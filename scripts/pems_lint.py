#!/usr/bin/env python
"""Entry point for pems-lint without an installed package.

Equivalent to ``PYTHONPATH=src python -m repro.lint``; stdlib-only, so CI
runs it before any install step.  See ``python scripts/pems_lint.py
--list-rules`` and docs/ARCHITECTURE.md ("Invariants").
"""

import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))

from repro.lint.__main__ import main   # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
