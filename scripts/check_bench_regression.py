"""CI benchmark regression gates.

Three schemas, dispatched on the files' ``benchmark`` field:

* ``alltoallv`` (``BENCH_alltoallv.smoke.json``): the *paired-sample*
  statistic — ``speedup_vs_dense`` is the median of per-iteration
  (dense / fused) wall-time ratios, where each pair ran back-to-back in the
  same process, so machine speed cancels and the ratio transfers across
  runner generations.  The gate fails when the kernel path loses more than
  ``--threshold`` (default 30%) of its advantage on any matched config.

* ``io_engine`` (``BENCH_io.smoke.json``): the async executor's measured
  compute/I-O ``overlap_fraction`` per (io_driver, exec_driver) row must not
  collapse below the baseline by more than ``--overlap-slack`` (absolute;
  overlap is already a within-run ratio, so it transfers across machines).
  ``odirect`` rows are *skipped with a notice* when the two runs disagree on
  the O_DIRECT fallback (a CI filesystem without O_DIRECT must take the
  documented buffered fallback, not fail the gate) — but missing rows still
  fail, so a crashed sweep cannot read as green.  ``checksum=true`` rows are
  additionally held to ``--checksum-overhead`` (default 15%) wall-time
  overhead against their checksum-off twin *within the new run*, bounding
  the cost of the per-block CRC sidecar.

* ``psrs_phases`` (``BENCH_psrs.smoke.json``): the merge-stage gate.  Each
  ``merge`` row is the same paired-sample statistic as ``alltoallv``
  (median per-iteration dense/kernel ratio on authentic post-delivery
  buckets), held to the *stricter* of the relative floor
  (``baseline / --threshold``) and the absolute ``--merge-floor`` (default
  1.15) — a silent fallback to the dense re-sort reads speedup ≈ 1.0 and
  fails the absolute floor no matter what the baseline says.  ``stream``
  rows (PSRS on a disk backing) must keep ``merge_prefetch_events`` > 0 in
  the *new* run: a streamed merge that stopped submitting bucket reads
  ahead of need is a regression even when wall time looks fine.  Missing
  rows of either kind fail.  The ``obs`` row's paired traced-vs-untraced
  wall-time ratio is capped at ``--obs-overhead`` (default 1.15) — the
  span tracer must stay cheap enough to leave on.

A machine-class guard skips the comparison (exit 0 with a notice) when the
two files disagree on backend or sweep shape — a CPU baseline says nothing
about a TPU runner.

    python scripts/check_bench_regression.py \
        --baseline /tmp/baseline.json --new BENCH_alltoallv.smoke.json
    python scripts/check_bench_regression.py \
        --baseline /tmp/io_baseline.json --new BENCH_io.smoke.json
"""

from __future__ import annotations

import argparse
import json
import sys


def load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def check_io(base: dict, new: dict, overlap_slack: float,
             checksum_overhead: float) -> int:
    def key(r):
        return (r["io_driver"], r["exec_driver"], r.get("checksum", False))

    base_rows = {key(r): r for r in base["psrs"]}
    new_rows = {key(r): r for r in new["psrs"]}
    missing = sorted(set(base_rows) - set(new_rows))
    if missing:
        print(f"FAIL: baseline psrs rows missing from the new run: {missing}")
        return 1
    eng_key = ("driver", "queue_depth", "block_bytes")
    base_eng = {tuple(r[k] for k in eng_key) for r in base["engine"]}
    new_eng = {tuple(r[k] for k in eng_key) for r in new["engine"]}
    missing_eng = sorted(base_eng - new_eng)
    if missing_eng:
        # A sweep that silently dropped configs (crash, trimmed DRIVERS)
        # must not read as a green gate.
        print(f"FAIL: baseline engine rows missing from the new run: "
              f"{missing_eng}")
        return 1
    bad = [r for r in new["engine"] if not r.get("data_ok", True)]
    if bad:
        print(f"FAIL: engine round-trip verification failed: "
              f"{[(r['driver'], r['queue_depth']) for r in bad]}")
        return 1

    failures = []
    for key in sorted(base_rows):
        b, n = base_rows[key], new_rows[key]
        if key[0] == "odirect" and b.get("fallback") != n.get("fallback"):
            print(f"SKIP {key}: O_DIRECT fallback differs "
                  f"(baseline={b.get('fallback')} new={n.get('fallback')}) "
                  "— documented buffered fallback taken, not comparable")
            continue
        floor = max(0.0, b["overlap_fraction"] - overlap_slack)
        status = "ok" if n["overlap_fraction"] >= floor else "REGRESSED"
        print(f"io={key[0]:9s} exec={key[1]:9s}: overlap "
              f"baseline={b['overlap_fraction']:.3f} "
              f"new={n['overlap_fraction']:.3f} floor={floor:.3f} [{status}]")
        if status != "ok":
            failures.append(key)
    if failures:
        print(f"FAIL: async overlap collapsed by more than {overlap_slack} "
              f"vs the committed baseline on rows {failures}")
        return 1

    # Integrity-cost gate: each checksum-on row is compared *within the new
    # run* against its checksum-off twin (same io/exec driver), so machine
    # speed cancels; the sidecar must stay cheap.
    crc_failures = []
    for k in sorted(k for k in new_rows if k[2]):
        r = new_rows[k]
        if "checksum_overhead" in r:        # paired min-of-2 from the bench
            over = r["checksum_overhead"]
        else:
            twin = new_rows.get((k[0], k[1], False))
            if twin is None:
                continue
            over = r["wall_s"] / twin["wall_s"] - 1.0
        status = "ok" if over <= checksum_overhead else "REGRESSED"
        print(f"io={k[0]:9s} exec={k[1]:9s}: checksum overhead "
              f"{over * 100:+.1f}% (limit {checksum_overhead * 100:.0f}%) "
              f"[{status}]")
        if status != "ok":
            crc_failures.append(k)
    if crc_failures:
        print(f"FAIL: per-block checksum overhead exceeded "
              f"{checksum_overhead * 100:.0f}% on rows {crc_failures}")
        return 1
    print(f"OK: io-engine overlap within {overlap_slack} of the committed "
          f"baseline on all compared rows")
    return 0


def check_psrs(base: dict, new: dict, threshold: float,
               merge_floor: float, obs_overhead: float) -> int:
    def key(r):
        return (r["n_words"], r["tile"])

    base_rows = {key(r): r for r in base["merge"]}
    new_rows = {key(r): r for r in new["merge"]}
    missing = sorted(set(base_rows) - set(new_rows))
    if missing:
        print(f"FAIL: baseline merge rows missing from the new run "
              f"(n_words, tile): {missing}")
        return 1

    failures = []
    for k in sorted(base_rows):
        b, n = base_rows[k], new_rows[k]
        # The absolute floor is what catches a silent fallback to the dense
        # path (speedup ≈ 1.0) even if the committed baseline ever degraded.
        floor = max(merge_floor, b["speedup_vs_dense"] / threshold)
        status = "ok" if n["speedup_vs_dense"] >= floor else "REGRESSED"
        print(f"n_words={k[0]:>8} tile={k[1]:>5}: merge paired speedup "
              f"baseline={b['speedup_vs_dense']:.3f} "
              f"new={n['speedup_vs_dense']:.3f} floor={floor:.3f} [{status}]")
        if status != "ok":
            failures.append(k)
    if failures:
        print(f"FAIL: merge kernel lost its paired advantage (floor = "
              f"max({merge_floor}, baseline/{threshold})) on rows {failures}")
        return 1

    def skey(r):
        return (r["tier"], r["driver"])

    base_stream = {skey(r) for r in base["stream"]}
    new_stream = {skey(r): r for r in new["stream"]}
    missing_s = sorted(base_stream - set(new_stream))
    if missing_s:
        print(f"FAIL: baseline stream rows missing from the new run: "
              f"{missing_s}")
        return 1
    dead = []
    for k in sorted(new_stream):
        r = new_stream[k]
        ev = r["merge_prefetch_events"]
        status = "ok" if ev > 0 else "REGRESSED"
        print(f"tier={k[0]:7s} driver={k[1]:9s}: merge_prefetch_events={ev} "
              f"stall={r['merge_stall_s']:.4f}s [{status}]")
        if status != "ok":
            dead.append(k)
    if dead:
        print(f"FAIL: streamed merge submitted no prefetch reads on rows "
              f"{dead} — the stage stopped overlapping disk with compute")
        return 1

    # Tracing-overhead gate: the obs row's paired (traced / untraced)
    # ratio is within-run, so machine speed cancels; the ceiling is
    # absolute.  A baseline with the row FAILs a new run without it — a
    # sweep that silently dropped the traced leg must not read as green.
    if base.get("obs") is not None:
        obs = new.get("obs")
        if obs is None:
            print("FAIL: baseline has an obs overhead row but the new run "
                  "has none")
            return 1
        ratio = obs["overhead_ratio"]
        status = "ok" if ratio <= obs_overhead else "REGRESSED"
        print(f"obs: traced/untraced paired ratio {ratio:.3f} "
              f"(ceiling {obs_overhead:.2f}) [{status}]")
        if status != "ok":
            print(f"FAIL: tracing overhead {ratio:.3f}x exceeded the "
                  f"{obs_overhead:.2f}x ceiling — the instrumented hot "
                  "path got too expensive")
            return 1
    print(f"OK: merge paired speedup above max({merge_floor}, "
          f"baseline/{threshold}) on all {len(base_rows)} rows, every "
          "streamed merge still prefetches, and tracing overhead is "
          "within the ceiling")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--new", required=True)
    ap.add_argument("--threshold", type=float, default=1.30,
                    help="max allowed paired-ratio regression factor")
    ap.add_argument("--overlap-slack", type=float, default=0.35,
                    help="io_engine gate: max allowed absolute drop in "
                         "overlap_fraction vs baseline")
    ap.add_argument("--checksum-overhead", type=float, default=0.15,
                    help="io_engine gate: max allowed wall-time overhead of "
                         "a checksum-on psrs row vs its checksum-off twin "
                         "(within the new run, so machine speed cancels)")
    ap.add_argument("--merge-floor", type=float, default=1.15,
                    help="psrs_phases gate: absolute minimum paired merge "
                         "speedup_vs_dense (catches a silent fallback to "
                         "the dense re-sort regardless of baseline)")
    ap.add_argument("--obs-overhead", type=float, default=1.15,
                    help="psrs_phases gate: max allowed paired "
                         "traced/untraced wall-time ratio of the obs row "
                         "(within the new run, so machine speed cancels)")
    args = ap.parse_args()

    base = load(args.baseline)
    new = load(args.new)

    # Machine-class guard: paired ratios (and overlap fractions, which
    # depend on compute speed per round) transfer across machines of the
    # same class, not across backends (or differently-shaped sweeps).
    guard = ("benchmark", "backend", "smoke") \
        if base.get("benchmark") == "io_engine" \
        else ("benchmark", "backend", "v", "smoke")
    for key in guard:
        if base.get(key) != new.get(key):
            print(f"SKIP: machine-class mismatch on {key!r}: "
                  f"baseline={base.get(key)!r} new={new.get(key)!r}")
            return 0

    if base.get("benchmark") == "io_engine":
        return check_io(base, new, args.overlap_slack,
                        args.checksum_overhead)
    if base.get("benchmark") == "psrs_phases":
        return check_psrs(base, new, args.threshold, args.merge_floor,
                          args.obs_overhead)

    # P defaults to 1 so pre-mesh baselines keep matching.
    base_cfgs = {(c["v"], c.get("P", 1), c["n_words"]): c
                 for c in base["configs"]}
    new_cfgs = {(c["v"], c.get("P", 1), c["n_words"]): c
                for c in new["configs"]}
    matched = sorted(set(base_cfgs) & set(new_cfgs))
    if not matched:
        print("FAIL: no matched configs between baseline and new run")
        return 1
    missing = sorted(set(base_cfgs) - set(new_cfgs))
    if missing:
        # A sweep that silently dropped configs (e.g. the P=2 subprocess
        # degrading to an empty list) must not read as a green gate.
        print(f"FAIL: baseline configs missing from the new run: {missing}")
        return 1

    failures = []
    for key in matched:
        b, n = base_cfgs[key], new_cfgs[key]
        floor = b["speedup_vs_dense"] / args.threshold
        status = "ok" if n["speedup_vs_dense"] >= floor else "REGRESSED"
        print(f"v={key[0]} P={key[1]} n_words={key[2]:>8}: paired speedup "
              f"baseline={b['speedup_vs_dense']:.3f} "
              f"new={n['speedup_vs_dense']:.3f} floor={floor:.3f} [{status}]")
        if status != "ok":
            failures.append(key)

    if failures:
        print(f"FAIL: kernel path regressed >{(args.threshold - 1) * 100:.0f}% "
              f"vs committed baseline on configs {failures}")
        return 1
    print(f"OK: kernel path within {(args.threshold - 1) * 100:.0f}% of the "
          f"committed baseline on all {len(matched)} configs")
    return 0


if __name__ == "__main__":
    sys.exit(main())
