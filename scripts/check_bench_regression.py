"""CI benchmark regression gate for the fused Alltoallv kernel path.

Compares a fresh ``BENCH_alltoallv.smoke.json`` against the committed
baseline using the *paired-sample* statistic: ``speedup_vs_dense`` is the
median of per-iteration (dense / fused) wall-time ratios, where each pair
ran back-to-back in the same process — machine speed cancels, so the ratio
transfers across runner generations.  The gate fails when the kernel path
loses more than ``--threshold`` (default 30%) of its advantage over the
dense path on any matched config.

A machine-class guard skips the comparison (exit 0 with a notice) when the
two files disagree on backend or sweep shape — a CPU baseline says nothing
about a TPU runner.

    python scripts/check_bench_regression.py \
        --baseline /tmp/baseline.json --new BENCH_alltoallv.smoke.json
"""

from __future__ import annotations

import argparse
import json
import sys


def load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--new", required=True)
    ap.add_argument("--threshold", type=float, default=1.30,
                    help="max allowed paired-ratio regression factor")
    args = ap.parse_args()

    base = load(args.baseline)
    new = load(args.new)

    # Machine-class guard: paired ratios transfer across machines of the
    # same class, not across backends (or differently-shaped sweeps).
    for key in ("benchmark", "backend", "v", "smoke"):
        if base.get(key) != new.get(key):
            print(f"SKIP: machine-class mismatch on {key!r}: "
                  f"baseline={base.get(key)!r} new={new.get(key)!r}")
            return 0

    # P defaults to 1 so pre-mesh baselines keep matching.
    base_cfgs = {(c["v"], c.get("P", 1), c["n_words"]): c
                 for c in base["configs"]}
    new_cfgs = {(c["v"], c.get("P", 1), c["n_words"]): c
                for c in new["configs"]}
    matched = sorted(set(base_cfgs) & set(new_cfgs))
    if not matched:
        print("FAIL: no matched configs between baseline and new run")
        return 1
    missing = sorted(set(base_cfgs) - set(new_cfgs))
    if missing:
        # A sweep that silently dropped configs (e.g. the P=2 subprocess
        # degrading to an empty list) must not read as a green gate.
        print(f"FAIL: baseline configs missing from the new run: {missing}")
        return 1

    failures = []
    for key in matched:
        b, n = base_cfgs[key], new_cfgs[key]
        floor = b["speedup_vs_dense"] / args.threshold
        status = "ok" if n["speedup_vs_dense"] >= floor else "REGRESSED"
        print(f"v={key[0]} P={key[1]} n_words={key[2]:>8}: paired speedup "
              f"baseline={b['speedup_vs_dense']:.3f} "
              f"new={n['speedup_vs_dense']:.3f} floor={floor:.3f} [{status}]")
        if status != "ok":
            failures.append(key)

    if failures:
        print(f"FAIL: kernel path regressed >{(args.threshold - 1) * 100:.0f}% "
              f"vs committed baseline on configs {failures}")
        return 1
    print(f"OK: kernel path within {(args.threshold - 1) * 100:.0f}% of the "
          f"committed baseline on all {len(matched)} configs")
    return 0


if __name__ == "__main__":
    sys.exit(main())
