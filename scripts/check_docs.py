#!/usr/bin/env python3
"""Docs consistency gate (CI `docs` job; no third-party deps, no jax).

Two checks:

1. Every relative markdown link in README.md, ROADMAP.md, and docs/*.md
   resolves to an existing file (anchors stripped; http(s) links skipped).
2. Every `PemsConfig` field — read from the dataclass source by AST, so the
   gate cannot drift from the code — is documented in docs/TUNING.md.

Exit code 0 when both pass; 1 with a per-failure listing otherwise.
"""

import ast
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_CODE_FENCE_RE = re.compile(r"```.*?```", re.S)


def iter_md_files():
    yield ROOT / "README.md"
    yield ROOT / "ROADMAP.md"
    yield from sorted((ROOT / "docs").glob("*.md"))


def check_links():
    errors = []
    for md in iter_md_files():
        text = _CODE_FENCE_RE.sub("", md.read_text())
        for target in _LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path = target.split("#", 1)[0]
            if not path:                     # pure in-page anchor
                continue
            if not (md.parent / path).exists():
                errors.append(f"{md.relative_to(ROOT)}: broken link {target!r}")
    return errors


def pems_config_fields():
    src = (ROOT / "src/repro/core/executor.py").read_text()
    tree = ast.parse(src)
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == "PemsConfig":
            return [stmt.target.id for stmt in node.body
                    if isinstance(stmt, ast.AnnAssign)
                    and isinstance(stmt.target, ast.Name)]
    raise SystemExit("PemsConfig not found in src/repro/core/executor.py")


def check_tuning_coverage():
    fields = pems_config_fields()
    if not fields:
        return ["PemsConfig has no annotated fields?"]
    tuning = (ROOT / "docs/TUNING.md").read_text()
    return [f"docs/TUNING.md: PemsConfig field `{f}` is undocumented"
            for f in fields if f"`{f}`" not in tuning]


def main():
    errors = check_links() + check_tuning_coverage()
    for e in errors:
        print(f"FAIL: {e}")
    if errors:
        return 1
    n = len(pems_config_fields())
    print(f"docs OK: links resolve, all {n} PemsConfig fields covered")
    return 0


if __name__ == "__main__":
    sys.exit(main())
