"""Per-kernel validation: shape/dtype sweeps in interpret mode against the
pure-jnp oracles, plus hypothesis property tests."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.kernels.alltoallv_deliver.ops import deliver
from repro.kernels.alltoallv_deliver.ref import deliver_ref
from repro.kernels.bitonic_sort.ops import sort as bitonic_sort
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.kway_merge import (
    kway_merge,
    kway_merge_ref,
    merge_tile_grid,
    sort_tile_rows,
)
from repro.kernels.lru_scan.ops import lru_scan
from repro.kernels.lru_scan.ref import lru_scan_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref

RNG = np.random.default_rng(42)


# --------------------------------------------------------------------------- #
# flash attention                                                              #
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("b,hq,hkv,sq,sk,d", [
    (1, 2, 2, 128, 128, 64),   # MHA
    (2, 4, 2, 64, 64, 32),     # GQA group 2
    (1, 8, 1, 96, 160, 64),    # MQA, uneven seqs
    (1, 2, 1, 33, 70, 16),     # non-block-aligned
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, hq, hkv, sq, sk, d, causal, dtype):
    q = jnp.asarray(RNG.normal(size=(b, hq, sq, d)), dtype)
    k = jnp.asarray(RNG.normal(size=(b, hkv, sk, d)), dtype)
    v = jnp.asarray(RNG.normal(size=(b, hkv, sk, d)), dtype)
    out = flash_attention(q, k, v, causal=causal, interpret=True,
                          block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol
    )


def test_flash_attention_matches_decode_pattern():
    """Sq=1 with a long KV (the serve_step decode shape)."""
    q = jnp.asarray(RNG.normal(size=(2, 4, 1, 64)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, 2, 333, 64)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, 2, 333, 64)), jnp.float32)
    out = flash_attention(q, k, v, causal=False, interpret=True)
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


# --------------------------------------------------------------------------- #
# bitonic sort                                                                 #
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("rows,n", [(1, 2), (4, 64), (2, 1000), (1, 4096)])
@pytest.mark.parametrize("dtype", [np.int32, np.float32])
def test_bitonic_sort_sweep(rows, n, dtype):
    if dtype == np.int32:
        x = RNG.integers(-2**31, 2**31 - 1, size=(rows, n)).astype(dtype)
    else:
        x = RNG.normal(size=(rows, n)).astype(dtype)
    out = bitonic_sort(jnp.asarray(x), interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.sort(x, axis=-1))


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(-2**31, 2**31 - 1), min_size=1, max_size=300))
def test_bitonic_sort_property(data):
    x = np.asarray(data, np.int32)
    out = bitonic_sort(jnp.asarray(x), interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.sort(x))


# --------------------------------------------------------------------------- #
# alltoallv direct delivery                                                    #
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("v,omega", [(2, 8), (6, 32), (8, 128), (4, 129)])
@pytest.mark.parametrize("dtype", [jnp.int32, jnp.float32])
def test_deliver_sweep(v, omega, dtype):
    msgs = jnp.asarray(RNG.normal(size=(v, v, omega)) * 100, dtype)
    cnts = jnp.asarray(RNG.integers(0, omega + 1, (v, v)), jnp.int32)
    out = deliver(msgs, cnts, interpret=True)
    ref = deliver_ref(msgs, cnts)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("v,omega", [(4, 129), (3, 200), (2, 257), (5, 64)])
@pytest.mark.parametrize("counts_kind", ["random", "zero", "full"])
def test_deliver_tiled_grid_equivalence(v, omega, counts_kind):
    """ω-tiled (v, v, ω/ωt) grid vs the oracle, covering ω that is not a
    multiple of the 128-lane tile, all-zero counts, and full counts."""
    msgs = jnp.asarray(RNG.normal(size=(v, v, omega)) * 100, jnp.int32)
    if counts_kind == "random":
        cnts = jnp.asarray(RNG.integers(0, omega + 1, (v, v)), jnp.int32)
    elif counts_kind == "zero":
        cnts = jnp.zeros((v, v), jnp.int32)
    else:
        cnts = jnp.full((v, v), omega, jnp.int32)
    out = deliver(msgs, cnts, fill=-3, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(deliver_ref(msgs, cnts, fill=-3))
    )


def test_deliver_fused_counts_transpose():
    """The counts transpose rides in the same pallas_call as a second
    output: ct[d, s] == counts_payload[s, d], bit-exact for raw words."""
    from repro.kernels.alltoallv_deliver import deliver_fused

    v, omega = 6, 130
    msgs = jnp.asarray(RNG.integers(-1000, 1000, (v, v, omega)), jnp.int32)
    cnts = jnp.asarray(RNG.integers(0, omega + 1, (v, v)), jnp.int32)
    cw = jnp.asarray(RNG.integers(0, 2**32, (v, v), dtype=np.uint32))

    out, ct = deliver_fused(msgs, cnts, cw, fill=-1, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(deliver_ref(msgs, cnts, fill=-1))
    )
    np.testing.assert_array_equal(np.asarray(ct), np.asarray(cw).T)

    # No fill → verbatim tile copy (pure permuted-BlockSpec delivery).
    out2, ct2 = deliver_fused(msgs, None, cw, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(out2), np.swapaxes(np.asarray(msgs), 0, 1)
    )
    np.testing.assert_array_equal(np.asarray(ct2), np.asarray(cw).T)


def test_deliver_auto_backend_matches_interpret():
    """interpret=None auto-selects a backend; the result must equal the
    interpret-mode kernel bit-for-bit."""
    v, omega = 4, 133
    msgs = jnp.asarray(RNG.integers(-1000, 1000, (v, v, omega)), jnp.int32)
    cnts = jnp.asarray(RNG.integers(0, omega + 1, (v, v)), jnp.int32)
    auto = deliver(msgs, cnts, fill=7)
    interp = deliver(msgs, cnts, fill=7, interpret=True)
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(interp))


def test_psrs_bit_identical_across_use_kernel():
    """End-to-end: psrs_sort through the fused kernel path and through the
    seed dense path must agree bit-for-bit (and with the oracle)."""
    from repro.pems_apps import psrs_sort
    x = RNG.integers(-2**30, 2**30, size=1024, dtype=np.int32)
    on = psrs_sort(x, v=8, k=2, use_kernel=True)
    off = psrs_sort(x, v=8, k=2, use_kernel=False)
    np.testing.assert_array_equal(on, off)
    np.testing.assert_array_equal(on, np.sort(x))


@pytest.mark.parametrize("s,Pn,d,omega", [
    (2, 2, 4, 8), (1, 4, 2, 129), (2, 3, 3, 200), (4, 2, 4, 64),
])
@pytest.mark.parametrize("counts_kind", ["random", "zero", "full"])
def test_assemble_proc_tiled_grid_equivalence(s, Pn, d, omega, counts_kind):
    """The (src_proc, dst_proc)-tiled mesh grid vs its oracle: the α-chunk
    [s, P, d, ω] is staged as out[p, dl, j] = msgs[j, p, dl], source-side
    boundary mask and counts transpose fused — covering ragged ω-tiles and
    degenerate counts."""
    from repro.kernels.alltoallv_deliver import assemble_proc_tiles
    from repro.kernels.alltoallv_deliver.ref import assemble_proc_ref

    msgs = jnp.asarray(RNG.integers(-1000, 1000, (s, Pn, d, omega)), jnp.int32)
    if counts_kind == "random":
        cnts = jnp.asarray(RNG.integers(0, omega + 1, (s, Pn, d)), jnp.int32)
    elif counts_kind == "zero":
        cnts = jnp.zeros((s, Pn, d), jnp.int32)
    else:
        cnts = jnp.full((s, Pn, d), omega, jnp.int32)
    cw = jnp.asarray(RNG.integers(0, 2**32, (s, Pn, d), dtype=np.uint32))

    out, ct = assemble_proc_tiles(msgs, cnts, cw, fill=-3, interpret=True)
    ro, rc = assemble_proc_ref(msgs, cnts, cw, fill=-3)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ro))
    np.testing.assert_array_equal(np.asarray(ct), np.asarray(rc))

    # No fill → verbatim permuted staging; no payload → single output.
    out2, ct2 = assemble_proc_tiles(msgs, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(out2), np.moveaxis(np.asarray(msgs), 0, 2)
    )
    assert ct2 is None


def test_assemble_proc_fused_auto_backend_matches_interpret():
    from repro.kernels.alltoallv_deliver import (
        assemble_proc_fused,
        assemble_proc_tiles,
    )

    s, Pn, d, omega = 2, 2, 3, 133
    msgs = jnp.asarray(RNG.integers(-1000, 1000, (s, Pn, d, omega)), jnp.int32)
    cnts = jnp.asarray(RNG.integers(0, omega + 1, (s, Pn, d)), jnp.int32)
    auto, _ = assemble_proc_fused(msgs, cnts, fill=7)
    interp, _ = assemble_proc_tiles(msgs, cnts, fill=7, interpret=True)
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(interp))


@pytest.mark.parametrize("dtype,bad_fill", [
    (jnp.int8, np.iinfo(np.int32).max),      # would wrap to -1
    (jnp.uint16, np.iinfo(np.int32).max),    # would wrap to 65535
    (jnp.uint16, -1),                        # negative on unsigned
    (jnp.int32, 2**31),                      # one past the max
    (jnp.uint32, -1),
])
def test_deliver_fill_out_of_range_rejected(dtype, bad_fill):
    """fill is cast to the payload dtype inside the kernel trace; an
    unrepresentable value used to wrap silently (fill=INT_MAX on int8
    arrives as -1).  Every delivery entry point now rejects it."""
    from repro.kernels.alltoallv_deliver import (
        assemble_proc_fused,
        check_fill_range,
        deliver_fused,
    )

    v, omega = 2, 8
    msgs = jnp.zeros((v, v, omega), dtype)
    cnts = jnp.ones((v, v), jnp.int32)
    with pytest.raises(ValueError, match="fill"):
        check_fill_range(bad_fill, dtype)
    with pytest.raises(ValueError, match="fill"):
        deliver(msgs, cnts, fill=bad_fill, interpret=True)
    with pytest.raises(ValueError, match="fill"):
        deliver_fused(msgs, cnts, fill=bad_fill, interpret=True)
    with pytest.raises(ValueError, match="fill"):
        assemble_proc_fused(msgs[:, None], cnts[:, None], fill=bad_fill,
                            interpret=True)


def test_deliver_fill_in_range_accepted():
    from repro.kernels.alltoallv_deliver import check_fill_range

    check_fill_range(np.iinfo(np.int32).max, jnp.int32)   # the PSRS sentinel
    check_fill_range(-128, jnp.int8)
    check_fill_range(65535, jnp.uint16)
    check_fill_range(2**32 - 1, jnp.uint32)
    check_fill_range(-1.5, jnp.float32)
    with pytest.raises(ValueError, match="fill"):
        check_fill_range(1e39, jnp.float32)               # overflows to inf
    with pytest.raises(ValueError, match="fill"):
        check_fill_range(2.5, jnp.int32)                  # non-integral


def test_alltoallv_fill_out_of_range_rejected():
    """The collective layer checks fill against the send field's dtype
    before any trace work on every implementation path."""
    from repro.core import ContextLayout, Pems, PemsConfig

    v = 4
    lo = (ContextLayout()
          .add("send", (v, 2), jnp.uint32).add("recv", (v, 2), jnp.uint32)
          .add("scnt", (v,), jnp.int32).add("rcnt", (v,), jnp.int32))
    for use_kernel in (True, False):
        pems = Pems(PemsConfig(v=v), lo)
        with pytest.raises(ValueError, match="fill"):
            pems.alltoallv(pems.init(), "send", "recv", "scnt", "rcnt",
                           fill=-1, use_kernel=use_kernel)


def test_deliver_boundary_masking():
    """The boundary fix-up: bytes past counts[s, d] never leak through."""
    v, omega = 4, 16
    msgs = jnp.full((v, v, omega), 7, jnp.int32)
    cnts = jnp.zeros((v, v), jnp.int32).at[1, 2].set(5)
    out = np.asarray(deliver(msgs, cnts, fill=-1, interpret=True))
    assert (out[2, 1, :5] == 7).all() and (out[2, 1, 5:] == -1).all()
    mask = np.ones((v, v), bool)
    mask[2, 1] = False
    assert (out[mask] == -1).all()


# --------------------------------------------------------------------------- #
# lru scan                                                                     #
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("b,s,d,chunk", [
    (1, 32, 8, 8), (2, 128, 16, 32), (1, 77, 4, 16), (3, 256, 2, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lru_scan_sweep(b, s, d, chunk, dtype):
    a = jnp.asarray(RNG.uniform(0.2, 0.999, (b, s, d)), dtype)
    x = jnp.asarray(RNG.normal(size=(b, s, d)), dtype)
    out = lru_scan(a, x, chunk=chunk, interpret=True)
    ref = lru_scan_ref(a, x)
    tol = 5e-2 if dtype == jnp.bfloat16 else 5e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol
    )


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([16, 48, 128]))
def test_lru_scan_property(seed, s):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.uniform(0.0, 1.0, (1, s, 4)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(1, s, 4)), jnp.float32)
    out = lru_scan(a, x, chunk=16, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(lru_scan_ref(a, x)), atol=1e-4
    )


# --------------------------------------------------------------------------- #
# ssd scan                                                                     #
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("b,h,s,p,n,chunk", [
    (1, 1, 32, 8, 4, 8),
    (2, 3, 64, 16, 8, 16),
    (1, 2, 100, 8, 16, 32),    # padded sequence
])
def test_ssd_scan_sweep(b, h, s, p, n, chunk):
    x = jnp.asarray(RNG.normal(size=(b, h, s, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.3, (b, h, s)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.3, 2.0, (h,)), jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32)
    out = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    ref = ssd_scan_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4)


def test_ssd_scan_chunk_invariance():
    """Chunk size is an implementation detail: results must match across
    chunkings (the EM block-size independence property)."""
    b, h, s, p, n = 1, 2, 64, 8, 8
    x = jnp.asarray(RNG.normal(size=(b, h, s, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.3, (b, h, s)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.3, 2.0, (h,)), jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32)
    outs = [
        np.asarray(ssd_scan(x, dt, A, Bm, Cm, chunk=c, interpret=True))
        for c in (8, 16, 64)
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, atol=2e-4)


# --------------------------------------------------------------------------- #
# k-way merge                                                                  #
# --------------------------------------------------------------------------- #

def _merge_case(v, cap, dtype, kind, rng=None):
    """Sorted buckets [v, cap] (garbage past counts, as after delivery) and
    per-bucket counts for the given input shape family."""
    rng = RNG if rng is None else rng
    info = np.iinfo(dtype)
    if kind == "random":
        raw = rng.integers(info.min, info.max, size=(v, cap),
                           dtype=dtype, endpoint=True)
    elif kind == "dups":          # duplicate-heavy: splitter tie-breaking
        raw = (rng.integers(-3, 4, size=(v, cap)) % np.uint64(2**32)
               ).astype(dtype) if dtype == np.uint32 else \
              rng.integers(-3, 4, size=(v, cap)).astype(dtype)
    elif kind == "fillmax":       # every lane at the fill sentinel
        raw = np.full((v, cap), info.max, dtype)
    else:                         # presorted: already globally ascending
        raw = np.sort(rng.integers(info.min, info.max, size=(v, cap),
                                   dtype=dtype, endpoint=True), axis=None
                      ).reshape(v, cap)
    counts = rng.integers(0, cap + 1, size=v).astype(np.int32)
    lane = np.arange(cap)
    buckets = raw.copy()
    for j in range(v):            # sort the valid prefix, garbage the rest
        buckets[j, :counts[j]] = np.sort(raw[j, :counts[j]])
        buckets[j, counts[j]:] = raw[j, ::-1][lane[counts[j]:] % cap]
    return buckets, counts


@pytest.mark.parametrize("v,cap,rcap", [
    (1, 64, 128), (2, 100, 200), (5, 17, 34), (8, 64, 128), (6, 50, 90),
])
@pytest.mark.parametrize("tile", [8, 64, 256])
@pytest.mark.parametrize("dtype", [np.int32, np.uint32])
@pytest.mark.parametrize("kind", ["random", "dups", "fillmax", "presorted"])
def test_kway_merge_sweep(v, cap, rcap, tile, dtype, kind):
    """Fallback path vs the oracle across shapes × tile widths × dtypes,
    including all-sentinel lanes, duplicate-heavy and presorted inputs."""
    buckets, counts = _merge_case(v, cap, dtype, kind)
    fill = int(np.iinfo(dtype).max)
    merged, total, over = kway_merge(
        jnp.asarray(buckets), jnp.asarray(counts), rcap=rcap, tile=tile,
        fill=fill, use_kernel=False)
    ref = kway_merge_ref(jnp.asarray(buckets), jnp.asarray(counts),
                         rcap=rcap, fill=fill)
    np.testing.assert_array_equal(np.asarray(merged), np.asarray(ref))
    assert int(total) == int(counts.sum())
    assert bool(over) == (int(counts.sum()) > rcap)


@pytest.mark.parametrize("v,cap,rcap,tile", [
    (2, 100, 200, 64), (8, 64, 128, 16), (3, 33, 50, 8), (6, 50, 90, 64),
])
def test_kway_merge_tile_grid_equivalence(v, cap, rcap, tile):
    """Interpret-mode Pallas grid vs the oracle and vs the batched jnp
    network: all three bit-identical."""
    buckets, counts = _merge_case(v, cap, np.int32, "random")
    fill = np.iinfo(np.int32).max
    grid, *_ = kway_merge(jnp.asarray(buckets), jnp.asarray(counts),
                          rcap=rcap, tile=tile, fill=fill, interpret=True)
    fall, *_ = kway_merge(jnp.asarray(buckets), jnp.asarray(counts),
                          rcap=rcap, tile=tile, fill=fill, use_kernel=False)
    ref = kway_merge_ref(jnp.asarray(buckets), jnp.asarray(counts),
                         rcap=rcap, fill=fill)
    np.testing.assert_array_equal(np.asarray(grid), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(fall), np.asarray(ref))


def test_kway_merge_auto_backend_matches_interpret():
    """interpret=None auto-selects a backend; must equal the interpret-mode
    grid bit-for-bit (the deliver kernel's dispatch contract)."""
    buckets, counts = _merge_case(4, 80, np.int32, "dups")
    fill = np.iinfo(np.int32).max
    auto, *_ = kway_merge(jnp.asarray(buckets), jnp.asarray(counts),
                          rcap=160, tile=32, fill=fill)
    interp, *_ = kway_merge(jnp.asarray(buckets), jnp.asarray(counts),
                            rcap=160, tile=32, fill=fill, interpret=True)
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(interp))


def test_kway_merge_sort_tile_rows_oracle():
    """The per-tile sort primitive alone: the batched bitonic network equals
    jnp-less numpy row sort, across widths and batch shapes."""
    for shape in ((3, 8), (3, 64), (5, 2, 16), (1, 32)):
        x = RNG.integers(-1000, 1000, size=shape).astype(np.int32)
        out = sort_tile_rows(jnp.asarray(x))
        np.testing.assert_array_equal(np.asarray(out), np.sort(x, axis=-1))
    u = RNG.integers(0, 2**32, size=(4, 128), dtype=np.uint64)
    u = u.astype(np.uint32)
    np.testing.assert_array_equal(
        np.asarray(sort_tile_rows(jnp.asarray(u))), np.sort(u, axis=-1))


def test_kway_merge_grid_matches_batched_network():
    """merge_tile_grid (interpret) over a [G, tile] batch equals the batched
    jnp network — the kernel body and the fallback are the same sort."""
    x = RNG.integers(-10**6, 10**6, size=(5, 64)).astype(np.int32)
    g = merge_tile_grid(jnp.asarray(x), interpret=True)
    t = sort_tile_rows(jnp.asarray(x))
    np.testing.assert_array_equal(np.asarray(g), np.asarray(t))


def test_kway_merge_validation():
    buckets = jnp.zeros((2, 8), jnp.int32)
    counts = jnp.ones((2,), jnp.int32)
    imax = np.iinfo(np.int32).max
    with pytest.raises(ValueError, match="tile"):
        kway_merge(buckets, counts, rcap=4, tile=12, fill=imax)
    with pytest.raises(ValueError, match="rcap"):
        kway_merge(buckets, counts, rcap=0, fill=imax)
    with pytest.raises(ValueError, match="fill"):
        kway_merge(buckets, counts, rcap=4, fill=0)
    with pytest.raises(ValueError, match="dtypes"):
        kway_merge(jnp.zeros((2, 8), jnp.float32), counts, rcap=4,
                   fill=np.finfo(np.float32).max)
    with pytest.raises(ValueError, match="buckets"):
        kway_merge(jnp.zeros((8,), jnp.int32), counts, rcap=4, fill=imax)


def test_kway_merge_overflow_boundary():
    """total == rcap ± 1 at the op level: the flag trips exactly when the
    received population exceeds rcap, and the merged prefix is still the
    correct lowest-rcap either way."""
    v, cap = 4, 32
    buckets, counts = _merge_case(v, cap, np.int32, "random")
    total = int(counts.sum())
    assert total >= 2
    fill = np.iinfo(np.int32).max
    for rcap, expect in ((total - 1, 1), (total, 0), (total + 1, 0)):
        merged, tot, over = kway_merge(
            jnp.asarray(buckets), jnp.asarray(counts), rcap=rcap, tile=16,
            fill=fill, use_kernel=False)
        assert int(tot) == total and int(over) == expect
        ref = kway_merge_ref(jnp.asarray(buckets), jnp.asarray(counts),
                             rcap=rcap, fill=fill)
        np.testing.assert_array_equal(np.asarray(merged), np.asarray(ref))


def test_psrs_overflow_seam_rcap_boundary():
    """End-to-end regression for the rcap overflow seam: constant keys with
    v=2 land exactly n_v elements on each receiver (the global-index
    tie-break splits duplicate runs at the median), so rcap = n_v − 1 must
    raise OverflowError while n_v and n_v + 1 succeed — on both merge
    paths."""
    from repro.pems_apps import psrs_sort
    n_v, v, k = 64, 2, 2
    x = np.full(n_v * v, 7, dtype=np.int32)
    for merge_kernel in (True, False):
        with pytest.raises(OverflowError, match="rcap"):
            psrs_sort(x, v=v, k=k, rcap=n_v - 1, merge_kernel=merge_kernel)
        for rcap in (n_v, n_v + 1):
            out = psrs_sort(x, v=v, k=k, rcap=rcap,
                            merge_kernel=merge_kernel)
            np.testing.assert_array_equal(out, np.sort(x))


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 7),
       st.sampled_from([8, 32, 128]))
def test_kway_merge_property(seed, v, tile):
    rng = np.random.default_rng(seed)
    cap = int(rng.integers(1, 97))
    rcap = int(rng.integers(1, 2 * v * cap + 1))
    kind = ["random", "dups", "presorted"][seed % 3]
    buckets, counts = _merge_case(v, cap, np.int32, kind, rng=rng)
    fill = np.iinfo(np.int32).max
    merged, total, over = kway_merge(
        jnp.asarray(buckets), jnp.asarray(counts), rcap=rcap, tile=tile,
        fill=fill, use_kernel=False)
    ref = kway_merge_ref(jnp.asarray(buckets), jnp.asarray(counts),
                         rcap=rcap, fill=fill)
    np.testing.assert_array_equal(np.asarray(merged), np.asarray(ref))
    assert int(total) == int(counts.sum())
    assert bool(over) == (int(counts.sum()) > rcap)


def test_psrs_bit_identical_across_merge_kernel():
    """psrs_sort with the tiled merge kernel vs the dense re-sort stage must
    agree bit-for-bit, across merge_tile widths."""
    from repro.pems_apps import psrs_sort
    x = RNG.integers(-2**30, 2**30, size=1024, dtype=np.int32)
    base = psrs_sort(x, v=8, k=2, merge_kernel=False)
    np.testing.assert_array_equal(base, np.sort(x))
    for tile in (16, 256, 1024):
        on = psrs_sort(x, v=8, k=2, merge_kernel=True, merge_tile=tile)
        np.testing.assert_array_equal(on, base)


# --------------------------------------------------------------------------- #
# PSRS with the bitonic kernel as the local sort                               #
# --------------------------------------------------------------------------- #

def test_psrs_with_bitonic_local_sort():
    from repro.pems_apps import psrs_sort
    import functools
    x = RNG.integers(-2**30, 2**30, size=512, dtype=np.int32)
    out = psrs_sort(
        x, v=4, k=2,
        local_sort=functools.partial(bitonic_sort, interpret=True),
    )
    np.testing.assert_array_equal(out, np.sort(x))


def test_psrs_default_local_sort_is_bitonic_kernel():
    """With use_kernel=True (default) the local sort resolves to the bitonic
    kernel wrapper; use_kernel=False keeps jnp.sort — both bit-identical."""
    from repro.pems_apps import psrs_sort
    x = RNG.integers(-2**31, 2**31 - 1, size=2048, dtype=np.int32)
    on = psrs_sort(x, v=4, k=2)
    off = psrs_sort(x, v=4, k=2, use_kernel=False)
    np.testing.assert_array_equal(on, off)
    np.testing.assert_array_equal(on, np.sort(x))
