"""Per-architecture smoke tests (reduced same-family configs): one forward +
one backward on CPU asserting output shapes and no NaNs, plus prefill/decode
equivalence, MoE dispatch vs dense oracle, chunked-attention equivalence, and
the exact full-size config values."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, REGISTRY, applicable_shapes, get_config
from repro.models import Model
from repro.models.blocks import moe_apply, moe_apply_dense_oracle, moe_params

RNG = jax.random.PRNGKey(0)
NP_RNG = np.random.default_rng(0)
B, S = 2, 24


def make_batch(cfg, b=B, s=S, batch_rng=None):
    r = batch_rng or NP_RNG
    if cfg.frontend == "frames":
        return {
            "frames": jnp.asarray(r.normal(size=(b, s, cfg.d_model)),
                                  jnp.float32),
            "labels": jnp.asarray(r.integers(0, cfg.vocab, (b, s)), jnp.int32),
        }
    s_text = s - (cfg.n_frontend_tokens if cfg.frontend == "patches" else 0)
    batch = {"tokens": jnp.asarray(
        r.integers(0, cfg.vocab, (b, s_text)), jnp.int32)}
    if cfg.frontend == "patches":
        batch["patches"] = jnp.asarray(
            r.normal(size=(b, cfg.n_frontend_tokens, cfg.d_model)),
            jnp.float32)
    return batch


# --------------------------------------------------------------------------- #
# Smoke: forward + train step per arch                                         #
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_forward_backward(name):
    cfg = get_config(name).smoke()
    m = Model(cfg)
    params = m.init(RNG)
    batch = make_batch(cfg)

    logits, aux = m.logits(params, batch)
    s_expect = S if cfg.frontend != "patches" else S
    assert logits.shape == (B, s_expect, cfg.vocab), logits.shape
    assert bool(jnp.isfinite(logits).all())

    loss, metrics = m.loss(params, batch)
    assert np.isfinite(float(loss))

    grads, _ = jax.grad(lambda p: m.loss(p, batch), has_aux=True)(params)
    for path, g in jax.tree_util.tree_leaves_with_path(grads):
        assert bool(jnp.isfinite(g).all()), path
    gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
                for g in jax.tree.leaves(grads))
    assert gnorm > 0.0


@pytest.mark.parametrize("name", [n for n in ARCH_NAMES
                                  if not REGISTRY[n].is_encoder_only])
def test_prefill_decode_matches_full_forward(name):
    cfg = get_config(name).smoke()
    m = Model(cfg)
    params = m.init(RNG)
    r = np.random.default_rng(1)
    s_text = S - (cfg.n_frontend_tokens if cfg.frontend == "patches" else 0)
    batch = make_batch(cfg, b=1, s=S, batch_rng=r)
    toks = batch["tokens"]

    full_logits, _ = m.logits(params, batch)

    n_pre = s_text - 4
    cache = m.init_cache(1, S + 8)
    last, cache = m.prefill(params, dict(batch, tokens=toks[:, :n_pre]), cache)
    s_pre = n_pre + (cfg.n_frontend_tokens if cfg.frontend == "patches" else 0)
    errs = [float(jnp.abs(last[:, 0] - full_logits[:, s_pre - 1]).max())]
    pos = s_pre
    for t in range(n_pre, s_text):
        lg, cache = m.decode(params, toks[:, t:t + 1], jnp.int32(pos), cache)
        errs.append(float(jnp.abs(lg[:, 0] - full_logits[:, pos]).max()))
        pos += 1
    assert max(errs) < 2e-2, errs


# --------------------------------------------------------------------------- #
# Exact full-size configs (the assignment's numbers)                           #
# --------------------------------------------------------------------------- #

EXACT = {
    "paligemma-3b": dict(n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
                         d_ff=16384, vocab=257216),
    "qwen2-1.5b": dict(n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
                       d_ff=8960, vocab=151936, qkv_bias=True),
    "qwen2.5-3b": dict(n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2,
                       d_ff=11008, vocab=151936, qkv_bias=True),
    "yi-6b": dict(n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4,
                  d_ff=11008, vocab=64000),
    "qwen3-14b": dict(n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8,
                      d_ff=17408, vocab=151936, qk_norm=True),
    "hubert-xlarge": dict(n_layers=48, d_model=1280, n_heads=16,
                          n_kv_heads=16, d_ff=5120, vocab=504, causal=False),
    "recurrentgemma-2b": dict(n_layers=26, d_model=2560, n_heads=10,
                              n_kv_heads=1, d_ff=7680, vocab=256000,
                              lru_width=2560, local_window=2048),
    "kimi-k2-1t-a32b": dict(n_layers=61, d_model=7168, n_heads=64,
                            n_kv_heads=8, d_ff=2048, vocab=163840,
                            n_experts=384, top_k=8),
    "arctic-480b": dict(n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
                        d_ff=4864, vocab=32000, n_experts=128, top_k=2,
                        moe_dense_residual=True),
    "mamba2-130m": dict(n_layers=24, d_model=768, d_ff=0, vocab=50280,
                        ssm_state=128),
}


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_full_config_values(name):
    cfg = get_config(name)
    for k, v in EXACT[name].items():
        assert getattr(cfg, k) == v, (name, k, getattr(cfg, k), v)


def test_shape_skip_rules():
    assert applicable_shapes(get_config("hubert-xlarge")) == [
        "train_4k", "prefill_32k"]
    assert "long_500k" in applicable_shapes(get_config("mamba2-130m"))
    assert "long_500k" in applicable_shapes(get_config("recurrentgemma-2b"))
    for n in ["qwen3-14b", "kimi-k2-1t-a32b", "paligemma-3b"]:
        shapes = applicable_shapes(get_config(n))
        assert "long_500k" not in shapes and "decode_32k" in shapes


# --------------------------------------------------------------------------- #
# MoE dispatch: EM capacity dispatch == dense oracle when nothing drops        #
# --------------------------------------------------------------------------- #

def test_moe_em_dispatch_matches_dense_oracle():
    cfg = get_config("kimi-k2-1t-a32b").smoke()
    p = moe_params(RNG, cfg)
    x = jnp.asarray(NP_RNG.normal(size=(2, 16, cfg.d_model)), jnp.float32)
    y_em, _ = moe_apply(cfg, p, x)
    y_dense = moe_apply_dense_oracle(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y_em), np.asarray(y_dense),
                               atol=1e-4)


def test_moe_capacity_drops():
    """With capacity_factor << 1 tokens are dropped, output differs, and no
    NaNs appear — exercises the overflow path the EM dispatch shares with the
    thesis' ω bound."""
    import dataclasses
    cfg = dataclasses.replace(get_config("arctic-480b").smoke(),
                              capacity_factor=0.25)
    p = moe_params(RNG, cfg)
    x = jnp.asarray(NP_RNG.normal(size=(2, 32, cfg.d_model)), jnp.float32)
    y_em, aux = moe_apply(cfg, p, x)
    assert bool(jnp.isfinite(y_em).all()) and np.isfinite(float(aux))
    y_dense = moe_apply_dense_oracle(cfg, p, x)
    assert float(jnp.abs(y_em - y_dense).max()) > 1e-6


# --------------------------------------------------------------------------- #
# Chunked attention == unchunked                                               #
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("window,prefix,causal", [
    (0, 0, True), (16, 0, True), (0, 8, True), (0, 0, False),
])
def test_chunked_attention_equivalence(window, prefix, causal):
    from repro.models.layers import attention
    r = np.random.default_rng(2)
    q = jnp.asarray(r.normal(size=(2, 40, 4, 16)), jnp.float32)
    k = jnp.asarray(r.normal(size=(2, 40, 2, 16)), jnp.float32)
    v = jnp.asarray(r.normal(size=(2, 40, 2, 16)), jnp.float32)
    ref = attention(q, k, v, causal=causal, window=window, prefix=prefix,
                    chunk=0)
    for chunk in (8, 16, 32):
        got = attention(q, k, v, causal=causal, window=window, prefix=prefix,
                        chunk=chunk)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=2e-5)


def test_ssd_jnp_twin_matches_kernel_ref():
    from repro.models.blocks import _ssd_chunked_jnp
    from repro.kernels.ssd_scan.ref import ssd_scan_ref
    r = np.random.default_rng(3)
    b, h, s, p, n = 2, 3, 48, 8, 4
    x = jnp.asarray(r.normal(size=(b, h, s, p)), jnp.float32)
    dt = jnp.asarray(r.uniform(0.01, 0.3, (b, h, s)), jnp.float32)
    A = jnp.asarray(-r.uniform(0.3, 2.0, (h,)), jnp.float32)
    Bm = jnp.asarray(r.normal(size=(b, s, n)), jnp.float32)
    Cm = jnp.asarray(r.normal(size=(b, s, n)), jnp.float32)
    y, _ = _ssd_chunked_jnp(x, dt, A, Bm, Cm, chunk=16)
    ref = ssd_scan_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=2e-4)


def test_lru_jnp_twin_matches_kernel_ref():
    from repro.models.blocks import _lru_chunked_jnp
    from repro.kernels.lru_scan.ref import lru_scan_ref
    r = np.random.default_rng(4)
    a = jnp.asarray(r.uniform(0.3, 0.99, (2, 40, 8)), jnp.float32)
    b = jnp.asarray(r.normal(size=(2, 40, 8)), jnp.float32)
    y, _ = _lru_chunked_jnp(a, b, chunk=16)
    np.testing.assert_allclose(np.asarray(y), np.asarray(lru_scan_ref(a, b)),
                               atol=1e-4)
