"""Full parallel disk model: ShardedBacking, per-shard ledgers, recovery.

Under ``P > 1`` on a backing tier each process owns a disjoint v/P-row shard
of the backing with its own engine/driver and its own ledger/stats.  These
tests pin the model's three contracts: bit-identity with the device
reference, per-shard accounting that sums to the P == 1 totals, and
per-process crash recovery after a single-disk failure.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import ContextLayout, Pems, PemsConfig
from repro.core.backing import make_backing, shard_row_ranges
from repro.core.iostats import IOLedger, TierStats
from repro.pems_apps.psrs import psrs_plan, psrs_run_recoverable


def _psrs_out(pems, load, steps, extract, data_blocks):
    st = load(data_blocks)
    for _, fn in steps:
        st = fn(st)
    result, rcount, oflow = extract(st)
    result = np.asarray(result)
    rcount = np.asarray(rcount)[:, 0]
    assert not np.asarray(oflow).any()
    v = result.shape[0]
    return np.concatenate([result[i, : rcount[i]] for i in range(v)]), st


# --------------------------------------------------------------------------- #
# Unit: the shard-splitting primitives                                         #
# --------------------------------------------------------------------------- #

def test_shard_row_ranges_splits_at_boundaries():
    # m=4 per shard: a [2, 11) block touches shards 0..2 with exact edges.
    assert list(shard_row_ranges(4, 2, 11)) == [(0, 2, 4), (1, 4, 8),
                                                (2, 8, 11)]
    assert list(shard_row_ranges(4, 4, 8)) == [(1, 4, 8)]
    assert list(shard_row_ranges(4, 7, 8)) == [(1, 7, 8)]


@pytest.mark.parametrize("tier", ("host", "memmap", "file"))
def test_sharded_backing_block_api_round_trip(tier, tmp_path):
    """Global-row read/write blocks crossing shard boundaries round-trip
    bit-identically, including column runs and broadcast writes."""
    v, words, P = 8, 6, 2
    bk = make_backing(tier, v, words, str(tmp_path / "bk"), P=P)
    assert len(bk.shards) == P and not hasattr(bk, "arr")
    rng = np.random.default_rng(3)
    full = rng.integers(0, 1 << 30, (v, words)).astype(np.int32)
    bk.write_block(0, v, full)
    bk.drain()
    np.testing.assert_array_equal(np.asarray(bk.read_block(0, v)), full)
    # Cross-boundary block with a column run.
    cols = [1, 2, 4]
    got = np.asarray(bk.read_block(2, 7, cols=cols))
    np.testing.assert_array_equal(got, full[2:7][:, cols])
    # Broadcast one row across the boundary.
    row = np.arange(words, dtype=np.int32)
    bk.write_block(3, 6, row[None])
    bk.drain()
    full[3:6] = row
    np.testing.assert_array_equal(np.asarray(bk.read_block(0, v)), full)
    bk.close()


def test_tier_stats_merge_sums_and_maxes():
    a, b = TierStats(), TierStats()
    a.rounds, b.rounds = 2, 3
    a.swap_in_s, b.swap_in_s = 0.5, 0.25
    a.peak_stage_bytes, b.peak_stage_bytes = 100, 300
    a.max_queue_depth, b.max_queue_depth = 4, 2
    m = a.merge(b)
    assert (m.rounds, m.swap_in_s) == (5, 0.75)
    assert m.peak_stage_bytes == 300 and m.max_queue_depth == 4


# --------------------------------------------------------------------------- #
# P=2 sharded PSRS: bit-identity with the device reference (subprocess)        #
# --------------------------------------------------------------------------- #

_P2_SHARDED_PSRS = textwrap.dedent("""
    import numpy as np, os, tempfile
    from repro.pems_apps.psrs import psrs_plan, psrs_sort

    rng = np.random.default_rng(11)
    n, v, k = 2048, 8, 2
    data = rng.integers(-2**31, 2**31 - 1, size=n, dtype=np.int32)
    ref = psrs_sort(data, v=v, k=k)          # P == 1 device-tier reference
    np.testing.assert_array_equal(ref, np.sort(data))
    blocks = np.asarray(data.reshape(v, n // v))

    def run(tier, driver, td, alpha=None):
        pems, load, steps, extract = psrs_plan(
            v, n // v, k=k, driver=driver, tier=tier,
            backing_path=os.path.join(td, "bk"), P=2, alpha=alpha)
        st = load(blocks)
        for _, fn in steps:
            st = fn(st)
        result, rcount, oflow = extract(st)
        result = np.asarray(result); rcount = np.asarray(rcount)[:, 0]
        assert not np.asarray(oflow).any()
        out = np.concatenate([result[i, :rcount[i]] for i in range(v)])
        return out, pems, st

    for tier in ("memmap", "file"):
        for driver in ("explicit", "sliced", "async"):
            with tempfile.TemporaryDirectory() as td:
                out, pems, st = run(tier, driver, td)
                np.testing.assert_array_equal(out, ref)
                bk = st.backing
                assert len(bk.shards) == 2
                assert os.path.exists(os.path.join(td, "bk.shard0"))
                assert os.path.exists(os.path.join(td, "bk.shard1"))
                if tier == "file":
                    e0 = bk.shards[0].engine
                    e1 = bk.shards[1].engine
                    assert e0 is not e1 and (e0.name, e1.name) == (
                        "shard0", "shard1")
                # Both shards did real measured work, independently.
                for led in pems.shard_ledgers:
                    assert led.disk_write_bytes > 0 and led.h2d_bytes > 0
    # α-chunked network phase on the sharded path: same bytes regardless.
    with tempfile.TemporaryDirectory() as td:
        out, pems, _ = run("file", "sliced", td, alpha=2)
        np.testing.assert_array_equal(out, ref)
    print("P2_SHARD_OK")
""")


def test_psrs_sharded_backing_bit_identity_subprocess():
    """P=2 sharded backing x {memmap, file} x every driver must reproduce
    the P == 1 device reference bit for bit, with a real shard file and a
    distinct engine per process."""
    r = subprocess.run(
        [sys.executable, "-c", _P2_SHARDED_PSRS],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")},
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert "P2_SHARD_OK" in r.stdout, r.stderr[-3000:]


# --------------------------------------------------------------------------- #
# Per-shard ledgers sum to the unsharded totals                                #
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("tier", ("memmap", "file"))
def test_sharded_ledger_sums_to_unsharded_totals(tier, tmp_path):
    """The parallel disk model re-routes every byte but invents none: the
    per-shard measured counters of the P=2 run sum exactly to the P=1 run's
    single-ledger totals, and modeled counters are untouched by sharding."""
    rng = np.random.default_rng(11)
    n, v, k = 2048, 8, 2
    data = rng.integers(0, 1 << 30, size=n, dtype=np.int32)
    blocks = np.asarray(data.reshape(v, n // v))

    def run(P, sub):
        pems, load, steps, extract = psrs_plan(
            v, n // v, k=k, driver="sliced", tier=tier,
            backing_path=str(tmp_path / sub / "bk"), P=P)
        out, _ = _psrs_out(pems, load, steps, extract, blocks)
        return out, pems

    (tmp_path / "p1").mkdir(); (tmp_path / "p2").mkdir()
    out1, pems1 = run(1, "p1")
    out2, pems2 = run(2, "p2")
    np.testing.assert_array_equal(out1, out2)

    assert len(pems2.shard_ledgers) == 2
    assert all(led is not pems2.ledger for led in pems2.shard_ledgers)
    merged = pems2.merged_shard_ledger()
    fields = ["disk_read_bytes", "disk_write_bytes", "h2d_bytes", "d2h_bytes"]
    if tier == "file":
        fields += ["syscall_read_bytes", "syscall_write_bytes"]
    for f in fields:
        assert getattr(merged, f) == getattr(pems1.ledger, f), f
        # ... and each shard genuinely carried part of the traffic.
        assert all(getattr(led, f) > 0 for led in pems2.shard_ledgers), f
    # Modeled counters live on the main ledger, once — and reflect the
    # parallel machine: at P=2 inter-process bytes are network traffic.
    assert pems1.ledger.network == 0 and pems2.ledger.network > 0
    assert pems2.ledger.network_rounds > 0
    assert all(led.network == 0 for led in pems2.shard_ledgers)


def test_sharded_stats_merge_matches_unsharded_rounds(tmp_path):
    """Each process's pipeline rounds are tracked in its own TierStats;
    merged they equal the P == 1 round count."""
    rng = np.random.default_rng(5)
    n, v, k = 1024, 8, 2
    blocks = rng.integers(0, 1 << 20, (v, n // v)).astype(np.int32)

    def run(P, sub):
        pems, load, steps, extract = psrs_plan(
            v, n // v, k=k, driver="sliced", tier="memmap",
            backing_path=str(tmp_path / sub / "bk"), P=P)
        _psrs_out(pems, load, steps, extract, blocks)
        return pems

    (tmp_path / "a").mkdir(); (tmp_path / "b").mkdir()
    p1, p2 = run(1, "a"), run(2, "b")
    assert len(p2.shard_stats) == 2
    assert all(s.rounds > 0 for s in p2.shard_stats)
    assert p2.merged_shard_stats().rounds == p1.tier_stats.rounds


# --------------------------------------------------------------------------- #
# Per-process staging respects the device cap                                  #
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("tier", ("memmap", "file"))
def test_sharded_alltoallv_per_process_staging_cap(tier):
    """The α-chunked network phase stages through per-process host buffers:
    with a device cap below the dense [v, v, ω] matrix, every process's own
    peak_stage_bytes stays under the cap and the result matches the device
    tier bit for bit."""
    v, omega, P = 8, 16, 2
    col_bytes = v * omega * 4
    cap = 5 * col_bytes
    lo = (ContextLayout()
          .add("send", (v, omega), jnp.int32)
          .add("recv", (v, omega), jnp.int32)
          .add("scnt", (v,), jnp.int32)
          .add("rcnt", (v,), jnp.int32))
    rng = np.random.default_rng(0)
    send = rng.integers(0, 100, (v, v, omega)).astype(np.int32)
    scnt = rng.integers(0, omega + 1, (v, v)).astype(np.int32)

    pems_d = Pems(PemsConfig(v=v, k=1, tier="device"), lo)
    st_d = pems_d.init().with_field("send", send).with_field("scnt", scnt)
    st_d = pems_d.alltoallv(st_d, "send", "recv", "scnt", "rcnt", fill=-1)
    want_r = np.asarray(st_d.field("recv"))
    want_c = np.asarray(st_d.field("rcnt"))

    pems = Pems(PemsConfig(v=v, k=1, P=P, tier=tier,
                           device_cap_bytes=cap), lo)
    st = pems.init().with_field("send", send).with_field("scnt", scnt)
    st = pems.alltoallv(st, "send", "recv", "scnt", "rcnt", fill=-1)
    np.testing.assert_array_equal(np.asarray(st.field("recv")), want_r)
    np.testing.assert_array_equal(np.asarray(st.field("rcnt")), want_c)
    for p in range(P):
        peak = pems.shard_stats[p].peak_stage_bytes
        assert 0 < peak <= cap, (p, peak, cap)


# --------------------------------------------------------------------------- #
# Single-shard fault: per-process recovery                                     #
# --------------------------------------------------------------------------- #

def test_single_shard_fault_recovers_per_process(tmp_path):
    """A seeded EIO on one shard's driver fails that process's stage only.
    The healthy process's cursor is already committed; the rerun re-executes
    the failed stage against the failed shard alone (zero resume I/O on the
    healthy shard) and the output is bit-identical to the reference."""
    rng = np.random.default_rng(11)
    n, v, k, P = 2048, 8, 2, 2
    data = rng.integers(0, 1 << 30, size=n, dtype=np.int32)
    ref = np.sort(data)
    state = str(tmp_path / "state")

    # Target the "result" field's byte range in row 0 of a shard file, so
    # the fault fires during the merge stage's writeback.
    probe, *_ = psrs_plan(v, n // v, k=k, P=P, tier="file",
                          backing_path=str(tmp_path / "probe"))
    lo_b = probe.layout.offset("result") * 4
    hi_b = lo_b + probe.layout.field_words("result") * 4 - 1

    kw = dict(v=v, k=k, P=P, driver="sliced", tier="file",
              state_dir=state, checksums=False)
    with pytest.raises(OSError, match="injected EIO"):
        psrs_run_recoverable(
            data, io_driver="faulty:buffered", io_retries=0,
            fault_spec=f"shard=1;seed=1;eio@wb{lo_b}-{hi_b}", **kw)

    import json
    c0 = json.load(open(os.path.join(state, "cursor.p0.json")))
    c1 = json.load(open(os.path.join(state, "cursor.p1.json")))
    last = 7                                 # merge (load is stage 0)
    assert c0["completed"] == last and c0["in_progress"] is None
    assert c1["completed"] == last - 1 and c1["in_progress"] == last

    out, pems = psrs_run_recoverable(data, io_driver="buffered",
                                     return_pems=True, **kw)
    np.testing.assert_array_equal(np.asarray(out), ref)
    # The healthy shard was not re-run: its resume traffic is zero.
    assert pems.shard_ledgers[0].disk_write_bytes == 0
    assert pems.shard_ledgers[0].h2d_bytes == 0
    # The failed shard re-ran its merge.
    assert pems.shard_ledgers[1].disk_write_bytes > 0


def test_shard_clause_requires_valid_shard():
    lo = ContextLayout().add("x", (4,), jnp.int32)
    with pytest.raises(ValueError, match="shard"):
        PemsConfig(v=8, k=2, P=2, tier="file", io_driver="faulty:buffered",
                   fault_spec="shard=5;eio@write")
