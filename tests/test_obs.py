"""repro.obs acceptance: tracer ring semantics, Perfetto export balance,
trace round-trips across the PSRS tier × P matrix (valid JSON, balanced
nesting, per-stage span counts, bit-identical results tracing on/off), the
report CLI's overlap cross-check against TierStats, the enriched drain
diagnostics, merge()/snapshot() shard-vs-single-process regression, and the
tracing overhead guard."""

import json
import os
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.core import PemsConfig
from repro.io import IOEngine, open_file
from repro.obs import NOOP, Tracer, load_trace, summarize, trace_events
from repro.pems_apps import psrs_sort
from repro.pems_apps.psrs import psrs_run_recoverable


# --------------------------------------------------------------------------- #
# Tracer semantics                                                             #
# --------------------------------------------------------------------------- #

def test_tracer_ring_bounds_and_drop_count():
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.instant(f"e{i}")
    assert len(tr) == 4
    assert tr.dropped == 6
    assert [e[1] for e in tr.events()] == ["e6", "e7", "e8", "e9"]
    tr.clear()
    assert len(tr) == 0 and tr.dropped == 0


def test_tracer_span_records_caller_timings():
    tr = Tracer()
    with tr.span("work", tid="lane", cat="compute", round=3) as sp:
        time.sleep(0.01)
    (ph, name, tid, ts, dur, cat, args), = tr.events()
    assert (ph, name, tid, cat) == ("X", "work", "lane", "compute")
    assert args == {"round": 3}
    assert dur == pytest.approx(sp.duration_s) and dur >= 0.01
    # complete() must bill exactly the caller's readings — the property the
    # stats/trace agreement rests on.
    tr.complete("x", 1.0 + tr.epoch, 3.5 + tr.epoch, tid="lane")
    ev = tr.events()[-1]
    assert ev[3] == pytest.approx(1.0) and ev[4] == pytest.approx(2.5)


def test_noop_tracer_is_inert():
    assert not NOOP.enabled
    with NOOP.span("x", tid="y") as sp:
        pass
    assert sp.duration_s == 0.0
    NOOP.begin("a")
    NOOP.end("a")
    NOOP.instant("b")
    NOOP.counter("c", 1)
    assert NOOP.events() == [] and len(NOOP) == 0


def test_config_rejects_trace_path_without_trace(tmp_path):
    with pytest.raises(ValueError, match="trace_path"):
        PemsConfig(v=4, k=1, trace_path=str(tmp_path / "t.json"))


# --------------------------------------------------------------------------- #
# Export balance sanitation                                                    #
# --------------------------------------------------------------------------- #

def _lane_balance(events):
    """Walk B/E nesting per (pid, tid) in file order; returns the leftover
    open-span count (asserting no orphan E on the way)."""
    stacks = {}
    for e in events:
        key = (e["pid"], e["tid"])
        if e["ph"] == "B":
            stacks.setdefault(key, []).append(e["name"])
        elif e["ph"] == "E":
            assert stacks.get(key), f"orphan E event: {e}"
            stacks[key].pop()
    return sum(len(s) for s in stacks.values())


def test_export_closes_dangling_begin_and_drops_orphan_end():
    tr = Tracer()
    tr.begin("outer", tid="lane")
    tr.begin("inner", tid="lane")
    tr.end("inner", tid="lane")
    # "outer" never ends (e.g. a crash): export must synthesize its close.
    evs = [e for e in trace_events(tr, pid=0) if e["ph"] in ("B", "E")]
    assert _lane_balance(evs) == 0
    assert [e["name"] for e in evs if e["ph"] == "E"][-1] == "outer"

    tr2 = Tracer()
    tr2.end("ghost", tid="lane")      # its B fell off the ring: dropped
    evs2 = [e for e in trace_events(tr2, pid=0) if e["ph"] in ("B", "E")]
    assert evs2 == []


# --------------------------------------------------------------------------- #
# PSRS trace round-trip matrix                                                 #
# --------------------------------------------------------------------------- #

_N, _V, _K = 2048, 8, 2
_STAGES = 7    # sort_sample .. merge — the psrs plan's stage count


def _keys(seed=17):
    rng = np.random.default_rng(seed)
    return rng.integers(-2**31, 2**31 - 1, size=_N, dtype=np.int32)


@pytest.mark.parametrize("tier, P", [
    ("device", 1), ("memmap", 1), ("memmap", 2), ("file", 1), ("file", 2),
])
def test_psrs_trace_roundtrip(tmp_path, tier, P):
    keys = _keys()
    ref = psrs_sort(keys, v=_V, k=_K, tier=tier, P=P,
                    backing_path=(None if tier == "device"
                                  else str(tmp_path / "ref.bin")))
    tp = str(tmp_path / "trace.json")
    out = psrs_sort(keys, v=_V, k=_K, tier=tier, P=P,
                    backing_path=(None if tier == "device"
                                  else str(tmp_path / "ctx.bin")),
                    trace=True, trace_path=tp)
    # Tracing must not perturb the computation.
    np.testing.assert_array_equal(out, ref)

    trace = load_trace(tp)                     # valid JSON by construction
    evs = trace["traceEvents"]
    for e in evs:
        assert {"ph", "pid", "tid", "name"} <= set(e)
    assert _lane_balance(evs) == 0
    stage = [e for e in evs if e.get("cat") == "stage"]
    assert len(stage) == _STAGES
    assert [e["name"] for e in stage] == [
        "stage:sort_sample", "stage:gather_samples", "stage:pick_splitters",
        "stage:bcast_splitters", "stage:partition", "stage:alltoallv",
        "stage:merge"]
    pids = {e["pid"] for e in evs}
    # pid 0 is the main tracer; disk tiers add one lane per shard process.
    assert pids == ({0} if tier == "device" else {0, *range(1, P + 1)})
    assert "metrics" in trace
    sup = [e for e in evs if e.get("cat") == "superstep"]
    assert len(sup) == 4                       # the four compute supersteps
    if tier != "device":
        assert any(e.get("cat") == "compute" for e in evs)
        assert any(e.get("cat") == "io" for e in evs)
    if tier == "file":
        # Engine request spans land on the shard engines' worker lanes.
        reqs = [e for e in evs if e.get("cat") == "request"]
        assert reqs and {e["pid"] for e in reqs} <= set(range(1, P + 1))
        assert {e["name"] for e in reqs} >= {"read", "write"}


def test_traced_overhead_is_bounded(tmp_path):
    """Paired min-of-N: tracing must cost ≤ 10% (plus a small absolute
    slack for scheduler noise) on the smoke-sized sort."""
    keys = _keys(3)

    def run(trace):
        t0 = time.perf_counter()
        psrs_sort(keys, v=_V, k=_K, tier="memmap", P=1,
                  backing_path=str(tmp_path / f"b{trace}.bin"), trace=trace)
        return time.perf_counter() - t0

    run(False), run(True)                      # warm both paths (jit etc.)
    plain = min(run(False) for _ in range(3))
    traced = min(run(True) for _ in range(3))
    assert traced <= plain * 1.10 + 0.05, (traced, plain)


# --------------------------------------------------------------------------- #
# Report: span-derived overlap vs TierStats (the acceptance cross-check)       #
# --------------------------------------------------------------------------- #

def test_report_overlap_matches_tierstats(tmp_path):
    tp = str(tmp_path / "trace.json")
    out, pems = psrs_sort(_keys(29), v=_V, k=_K, tier="file", P=2,
                          driver="async",
                          backing_path=str(tmp_path / "ctx.bin"),
                          trace=True, trace_path=tp, return_pems=True)
    trace = load_trace(tp)
    s = summarize(trace)
    stats = pems.merged_shard_stats()
    assert s["metrics_overlap"] == pytest.approx(stats.overlap_fraction)
    # Spans and counters are billed from the same perf_counter readings, so
    # the two overlap fractions must agree (acceptance bound: 5%).
    assert abs(s["overlap_fraction"] - s["metrics_overlap"]) <= 0.05
    # Per-shard engine lanes show I/O overlapping compute in wall time.
    evs = trace["traceEvents"]
    for pid in (1, 2):
        comp = [e for e in evs
                if e["pid"] == pid and e.get("cat") == "compute"]
        ios = [e for e in evs
               if e["pid"] == pid and e.get("cat") in ("io", "request")]
        assert comp and ios
        assert any(c["ts"] < r["ts"] + r.get("dur", 0.0)
                   and r["ts"] < c["ts"] + c.get("dur", 0.0)
                   for c in comp for r in ios)


def test_report_cli(tmp_path):
    tp = str(tmp_path / "trace.json")
    psrs_sort(_keys(5), v=_V, k=_K, tier="file", P=1, driver="async",
              backing_path=str(tmp_path / "ctx.bin"),
              trace=True, trace_path=tp)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.obs", "report", tp, "--top", "3"],
        capture_output=True, text=True, timeout=120, env=env)
    assert r.returncode == 0, r.stderr
    assert "overlap fraction (spans)" in r.stdout
    assert "overlap fraction (TierStats)" in r.stdout
    assert "stage:merge" in r.stdout


# --------------------------------------------------------------------------- #
# Recovery spans                                                               #
# --------------------------------------------------------------------------- #

def test_recoverable_run_traces_cursor_windows(tmp_path):
    tp = str(tmp_path / "trace.json")
    keys = _keys(7)
    out = psrs_run_recoverable(keys, v=_V, state_dir=str(tmp_path / "st"),
                               P=2, tier="file", trace=True, trace_path=tp)
    np.testing.assert_array_equal(out, np.sort(keys))
    evs = load_trace(tp)["traceEvents"]
    assert _lane_balance(evs) == 0
    rec = [e for e in evs if e.get("cat") == "recovery"]
    # 8 stages (load + 7) × 2 processes, begin+end each, plus snapshots.
    assert len([e for e in rec if e["ph"] == "B"]) == 16
    assert any(e["name"] == "snapshot:save" for e in rec)


# --------------------------------------------------------------------------- #
# Drain diagnostics (satellite: age + byte range + instant event)              #
# --------------------------------------------------------------------------- #

def test_drain_timeout_names_age_and_range(tmp_path):
    eng = IOEngine(open_file(str(tmp_path / "d.bin"), 1 << 16, "buffered"),
                   queue_depth=2)
    eng.tracer = Tracer()
    try:
        eng._gate.clear()                      # wedge the workers
        eng.submit_write(0, np.zeros(4096, np.uint8))
        with pytest.raises(TimeoutError) as ei:
            eng.drain(timeout=0.05)
        msg = str(ei.value)
        assert "[0,4096)" in msg and "age=" in msg
        inst = [e for e in eng.tracer.events() if e[0] == "i"]
        assert [e[1] for e in inst] == ["drain_timeout"]
        assert inst[0][6]["in_flight"] == 1
    finally:
        eng._gate.set()
        eng.close()


# --------------------------------------------------------------------------- #
# merge()/snapshot(): per-shard totals equal the single-process run            #
# --------------------------------------------------------------------------- #

def test_shard_merge_equals_single_process_totals(tmp_path):
    keys = _keys(41)
    _, p1 = psrs_sort(keys, v=_V, k=_K, tier="file", P=1,
                      backing_path=str(tmp_path / "p1.bin"),
                      return_pems=True)
    _, p2 = psrs_sort(keys, v=_V, k=_K, tier="file", P=2,
                      backing_path=str(tmp_path / "p2.bin"),
                      return_pems=True)
    merged = p2.shard_ledgers[0].merge(p2.shard_ledgers[1])
    snap1 = p1.ledger.snapshot()
    snap2 = merged.snapshot()
    for key in ("ledger.disk_read_bytes", "ledger.disk_write_bytes",
                "ledger.h2d_bytes", "ledger.d2h_bytes",
                "ledger.syscall_read_bytes", "ledger.syscall_write_bytes"):
        assert snap2[key] == snap1[key], key
    stats = p2.merged_shard_stats()
    assert stats.rounds == p1.tier_stats.rounds
    assert set(stats.snapshot()) == set(p1.tier_stats.snapshot())


def test_metrics_snapshot_subsumes_tierstats(tmp_path):
    _, pems = psrs_sort(_keys(2), v=_V, k=_K, tier="file", P=2,
                        backing_path=str(tmp_path / "m.bin"),
                        trace=True, return_pems=True)
    snap = pems.metrics_snapshot()
    stats = pems.merged_shard_stats()
    for k, val in stats.snapshot().items():
        assert snap[k] == val
    for k in pems.ledger.as_dict():
        assert f"ledger.{k}" in snap
    # Per-shard breakdown rides along at P > 1.
    assert "shard0.tier.rounds" in snap and "shard1.tier.rounds" in snap
