"""Out-of-core backing tier: driver × tier bit-identity (including the
2-process mesh extension of the identity matrix), measured ledger bytes vs
the backing file on disk, collective staging under the device cap, and
checkpoint→restore of a memmap-backed store resuming PSRS mid-stream."""

import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.core import ContextLayout, Pems, PemsConfig, TieredStore, WORD
from repro.pems_apps import prefix_sum, psrs_plan, psrs_sort

DRIVERS = ("explicit", "sliced", "async")
TIERS = ("device", "host", "memmap", "file")
DISK_TIERS = ("memmap", "file")


# --------------------------------------------------------------------------- #
# Bit-identity across the driver × tier matrix                                 #
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("driver", DRIVERS)
@pytest.mark.parametrize("tier", TIERS)
def test_psrs_driver_tier_bit_identity(driver, tier):
    rng = np.random.default_rng(11)
    n, v, k = 2048, 8, 2
    data = rng.integers(-2**31, 2**31 - 1, size=n, dtype=np.int32)
    ref = psrs_sort(data, v=v, k=k)          # device/explicit reference
    out, pems = psrs_sort(data, v=v, k=k, driver=driver, tier=tier,
                          return_pems=True)
    np.testing.assert_array_equal(out, ref)
    if tier != "device":
        assert pems.ledger.h2d_bytes > 0 and pems.ledger.d2h_bytes > 0
        assert (pems.ledger.disk_read_bytes > 0) == (tier in DISK_TIERS)
        assert (pems.ledger.syscall_read_bytes > 0) == (tier == "file")


@pytest.mark.parametrize("tier", ("host", "memmap", "file"))
def test_prefix_sum_tier_bit_identity(tier):
    rng = np.random.default_rng(5)
    x = rng.integers(-100, 100, size=1024, dtype=np.int32)
    ref = prefix_sum(x, v=8, k=4)
    for driver in DRIVERS:
        out = prefix_sum(x, v=8, k=4, driver=driver, tier=tier)
        np.testing.assert_array_equal(out, ref)


def test_superstep_tiered_matches_device_with_float_math():
    """Non-trivial float compute through the pipeline: results must be
    bit-identical because every tier traces the same round body."""
    v, k = 8, 2
    ref = {}
    for tier in TIERS:
        lo = ContextLayout().add("x", (32,), jnp.float32)
        pems = Pems(PemsConfig(v=v, k=k, driver="async", tier=tier), lo)
        store = pems.init(lambda rho: {"x": jnp.full(32, rho, jnp.float32)})

        def step(rho, ctx):
            x = ctx.get("x")
            return ctx.set("x", jnp.sin(x) * 2.0 + jnp.sqrt(jnp.abs(x)) + rho)

        store = pems.superstep(store, step)
        ref[tier] = np.asarray(store.field("x"))
    for tier in TIERS[1:]:
        np.testing.assert_array_equal(ref[tier], ref["device"], err_msg=tier)


def test_tiered_collectives_match_device():
    v = 4
    outs = {}
    for tier in TIERS:
        lo = (ContextLayout()
              .add("send", (v, 3), jnp.int32).add("recv", (v, 3), jnp.int32)
              .add("scnt", (v,), jnp.int32).add("rcnt", (v,), jnp.int32)
              .add("x", (5,), jnp.float32).add("o", (5,), jnp.float32)
              .add("g", (v, 5), jnp.float32))
        pems = Pems(PemsConfig(v=v, k=2, tier=tier), lo)
        rng = np.random.default_rng(0)
        st = (pems.init()
              .with_field("send", rng.integers(0, 100, (v, v, 3)).astype(np.int32))
              .with_field("scnt", rng.integers(0, 4, (v, v)).astype(np.int32))
              .with_field("x", rng.standard_normal((v, 5)).astype(np.float32)))
        st = pems.alltoallv(st, "send", "recv", "scnt", "rcnt", fill=-1)
        st = pems.bcast(st, "x", root=1)
        st = pems.gather(st, "x", "g", root=0)
        st = pems.reduce(st, "x", "o", op="add", root=2)
        st = pems.allgather(st, "x", "g")
        outs[tier] = {n: np.asarray(st.field(n))
                      for n in ("recv", "rcnt", "x", "o", "g")}
    for tier in TIERS[1:]:
        for name, arr in outs[tier].items():
            np.testing.assert_array_equal(arr, outs["device"][name],
                                          err_msg=f"{tier}:{name}")


# --------------------------------------------------------------------------- #
# 2-process mesh extension of the identity matrix (subprocess: fake devices)   #
# --------------------------------------------------------------------------- #

_P2_PSRS = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import jax, numpy as np
    from repro.pems_apps import psrs_sort

    # Same inputs as test_psrs_driver_tier_bit_identity, so the mesh runs
    # are pinned to the exact bytes the P == 1 identity matrix produces.
    rng = np.random.default_rng(11)
    n, v, k = 2048, 8, 2
    data = rng.integers(-2**31, 2**31 - 1, size=n, dtype=np.int32)
    ref = psrs_sort(data, v=v, k=k)          # P == 1 seed reference
    np.testing.assert_array_equal(ref, np.sort(data))

    mesh = jax.make_mesh((2,), ("vp",))
    for driver in ("explicit", "sliced", "async"):
        for use_kernel in (True, False):
            out = psrs_sort(data, v=v, k=k, driver=driver, P=2, mesh=mesh,
                            use_kernel=use_kernel)
            np.testing.assert_array_equal(out, ref)
    # α-chunked network phase: same bytes regardless of chunking.
    out = psrs_sort(data, v=v, k=k, P=2, mesh=mesh, alpha=2)
    np.testing.assert_array_equal(out, ref)
    print("P2_PSRS_OK")
""")


def test_psrs_driver_mesh_bit_identity_subprocess():
    """driver × use_kernel matrix on a 2-process CPU mesh: the fused
    (src_proc, dst_proc)-tiled delivery route must reproduce the P == 1
    seed reference bit for bit (and so must the dense route)."""
    r = subprocess.run(
        [sys.executable, "-c", _P2_PSRS],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
             # Without an explicit platform, jax probes for TPUs via the
             # cloud metadata URL and stalls for minutes off-cloud.
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")},
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert "P2_PSRS_OK" in r.stdout, r.stderr[-3000:]


# --------------------------------------------------------------------------- #
# Collective staging under the device cap                                      #
# --------------------------------------------------------------------------- #

def _collective_store(tier, alpha=None, cap=None, k=2, v=8, omega=16):
    lo = (ContextLayout()
          .add("send", (v, omega), jnp.int32)
          .add("recv", (v, omega), jnp.int32)
          .add("scnt", (v,), jnp.int32)
          .add("rcnt", (v,), jnp.int32))
    pems = Pems(PemsConfig(v=v, k=k, tier=tier, alpha=alpha,
                           device_cap_bytes=cap), lo)
    rng = np.random.default_rng(0)
    st = (pems.init()
          .with_field("send",
                      rng.integers(0, 100, (v, v, omega)).astype(np.int32))
          .with_field("scnt",
                      rng.integers(0, omega + 1, (v, v)).astype(np.int32)))
    return pems, st


@pytest.mark.parametrize("tier", ("host", "memmap", "file"))
def test_tiered_alltoallv_staging_respects_cap(tier):
    """Tiered Alltoallv staging is chunked by destination (the α knob):
    with a device cap that cannot hold the dense [v, v, ω] matrix, the
    per-chunk staging buffer stays within the cap and the result is still
    bit-identical to the device tier.  The file tier's chunks are read as
    copies (no view into the backing), so its staging counts 2x per chunk —
    still clamped under the cap."""
    v, omega = 8, 16
    col_bytes = v * omega * 4                  # one destination column
    dense_bytes = v * col_bytes                # the [v, v, ω] matrix
    copies = 2 if tier == "file" else 1        # read copy + staging buffer
    pems_d, st_d = _collective_store("device")
    st_d = pems_d.alltoallv(st_d, "send", "recv", "scnt", "rcnt", fill=-1)
    want_r = np.asarray(st_d.field("recv"))
    want_c = np.asarray(st_d.field("rcnt"))

    cap = 5 * col_bytes                        # fits 5 of 8 columns
    assert cap < dense_bytes
    pems, st = _collective_store(tier, cap=cap, k=1)
    st = pems.alltoallv(st, "send", "recv", "scnt", "rcnt", fill=-1)
    np.testing.assert_array_equal(np.asarray(st.field("recv")), want_r)
    np.testing.assert_array_equal(np.asarray(st.field("rcnt")), want_c)
    assert 0 < pems.tier_stats.peak_stage_bytes <= cap

    # The α knob chunks even without a cap; results stay bit-identical.
    for alpha in (1, 3, 8):
        pems, st = _collective_store(tier, alpha=alpha)
        st = pems.alltoallv(st, "send", "recv", "scnt", "rcnt", fill=-1)
        np.testing.assert_array_equal(np.asarray(st.field("recv")), want_r)
        np.testing.assert_array_equal(np.asarray(st.field("rcnt")), want_c)
        assert (pems.tier_stats.peak_stage_bytes
                <= copies * max(alpha, 1) * col_bytes)


def test_tiered_alltoallv_inplace_cap_refused():
    """send == recv must snapshot the whole field; with a cap that cannot
    hold snapshot + chunk the call refuses instead of silently blowing the
    budget (and still works uncapped, bit-identical to the device tier)."""
    v, omega = 8, 16
    lo = ContextLayout().add("send", (v, omega), jnp.int32)
    rng = np.random.default_rng(1)
    M = rng.integers(0, 100, (v, v, omega)).astype(np.int32)

    pems = Pems(PemsConfig(v=v, k=1, tier="host"), lo)
    st = pems.init().with_field("send", M)
    st = pems.alltoallv(st, "send", "send")
    np.testing.assert_array_equal(np.asarray(st.field("send")),
                                  np.swapaxes(M, 0, 1))

    cap = 5 * v * omega * 4                    # < field (v·v·ω) + chunk
    pems = Pems(PemsConfig(v=v, k=1, tier="host", device_cap_bytes=cap), lo)
    st = pems.init().with_field("send", M)
    with pytest.raises(ValueError, match="in-place"):
        pems.alltoallv(st, "send", "send")


def test_tiered_alltoallv_chunked_ledger_bytes():
    """Destination-chunked staging moves exactly the same measured bytes as
    the whole-field staging it replaced: the field once in each direction."""
    v, omega = 8, 16
    pems, st = _collective_store("memmap", alpha=2)
    r0, w0 = pems.ledger.disk_read_bytes, pems.ledger.disk_write_bytes
    st = pems.alltoallv(st, "send", "recv", "scnt", "rcnt", fill=-1)
    field_b = v * v * omega * 4
    counts_b = v * v * 4
    assert pems.ledger.disk_read_bytes - r0 == field_b + counts_b
    assert pems.ledger.disk_write_bytes - w0 == field_b + counts_b


def test_tiered_allgather_stages_one_row():
    """Tiered allgather stages only the gathered [v, ω] row, never the
    [v, v·ω] broadcast."""
    v = 8
    lo = (ContextLayout()
          .add("x", (4,), jnp.int32)
          .add("gath", (v, 4), jnp.int32))
    pems = Pems(PemsConfig(v=v, k=2, tier="host"), lo)
    st = pems.init().with_field(
        "x", (np.arange(v * 4).reshape(v, 4)).astype(np.int32))
    st = pems.allgather(st, "x", "gath")
    want = np.arange(v * 4).reshape(v, 4).astype(np.int32)
    for r in range(v):
        np.testing.assert_array_equal(np.asarray(st.field("gath"))[r], want)
    assert pems.tier_stats.peak_stage_bytes == v * 4 * 4


# --------------------------------------------------------------------------- #
# Measured ledger bytes vs the backing file                                    #
# --------------------------------------------------------------------------- #

def test_ledger_matches_backing_file_touched_ranges(tmp_path):
    """The measured counters equal the exact byte ranges the pipeline
    touches — live allocator words only (§6.6) — and the backing file is
    exactly the vμ the thesis requires (§6.3), written sparsely."""
    v, k, capacity = 8, 2, 64
    lo = (ContextLayout(capacity_words=capacity)
          .add("a", (8,), jnp.int32)
          .add("tmp", (16,), jnp.int32)
          .add("b", (8,), jnp.int32))
    lo.drop("tmp")                      # a live hole: only 16/64 words live
    assert lo.live_words == 16 and lo.words == capacity

    path = str(tmp_path / "ctx.bin")
    pems = Pems(PemsConfig(v=v, k=k, tier="memmap", backing_path=path), lo)
    store = pems.init()
    assert isinstance(store, TieredStore)
    st = os.stat(path)
    assert st.st_size == v * capacity * WORD
    sparse_file = st.st_blocks * 512 < st.st_size  # fs supports sparse files

    store = pems.superstep(
        store, lambda rho, c: c.set("a", c.get("a") + 1).set("b", c.get("b")))
    live_bytes = lo.live_words * WORD
    assert pems.ledger.h2d_bytes == v * live_bytes
    assert pems.ledger.d2h_bytes == v * live_bytes
    assert pems.ledger.disk_read_bytes == v * live_bytes
    assert pems.ledger.disk_write_bytes == v * live_bytes

    if sparse_file:
        # Only live ranges were written: the file's allocated blocks must
        # cover at most the touched pages, not the full vμ.
        touched = os.stat(path).st_blocks * 512
        page = 4096
        worst = v * (-(-capacity * WORD // page) + 1) * page
        assert touched <= worst

    # The sliced driver narrows further: only declared fields move.
    pems2 = Pems(PemsConfig(v=v, k=k, driver="sliced", tier="memmap",
                            backing_path=str(tmp_path / "ctx2.bin")), lo)
    store2 = pems2.init()
    store2 = pems2.superstep(store2, lambda rho, c: c.set("a", c.get("a") + 1),
                             reads=["a"], writes=["a"])
    a_bytes = lo.field_bytes("a")
    assert pems2.ledger.h2d_bytes == v * a_bytes
    assert pems2.ledger.disk_write_bytes == v * a_bytes


def test_modeled_ledger_identical_across_tiers():
    """The thesis' closed-form counters must not depend on the execution
    tier — same swap/message/barrier events everywhere."""
    x = np.arange(512, dtype=np.int32)
    base = None
    for tier in TIERS:
        _, pems = prefix_sum(x, v=8, k=2, tier=tier, return_pems=True)
        modeled = (pems.ledger.swap_in, pems.ledger.swap_out,
                   pems.ledger.message_total, pems.ledger.supersteps,
                   pems.ledger.num_ios)
        if base is None:
            base = modeled
        assert modeled == base, tier


def test_device_cap_enforced():
    lo = ContextLayout().add("x", (1024,), jnp.int32)   # μ = 4096 B
    cap = 4 * lo.mu_bytes                               # fits 4 contexts
    with pytest.raises(ValueError):
        Pems(PemsConfig(v=8, k=1, device_cap_bytes=cap), lo)   # 8μ on device
    with pytest.raises(ValueError):
        # sync tiered: 2·k·μ in-flight = 8μ > cap
        Pems(PemsConfig(v=8, k=4, tier="host", device_cap_bytes=cap), lo)
    with pytest.raises(ValueError):
        # async keeps a third (prefetched) block in flight: 3·2·μ > cap
        Pems(PemsConfig(v=8, k=2, driver="async", tier="host",
                        device_cap_bytes=cap), lo)
    Pems(PemsConfig(v=8, k=2, tier="host", device_cap_bytes=cap), lo)  # 2·2·μ
    Pems(PemsConfig(v=8, k=1, driver="async", tier="host",
                    device_cap_bytes=cap), lo)                         # 3·1·μ


# --------------------------------------------------------------------------- #
# Async overlap instrumentation                                                #
# --------------------------------------------------------------------------- #

def test_async_tier_records_overlap_stats():
    rng = np.random.default_rng(1)
    data = rng.integers(-1000, 1000, size=4096, dtype=np.int32)
    out, pems = psrs_sort(data, v=8, k=2, driver="async", tier="memmap",
                          return_pems=True)
    np.testing.assert_array_equal(out, np.sort(data))
    s = pems.tier_stats
    assert s.rounds > 0 and s.swap_in_s > 0 and s.compute_s > 0
    assert 0.0 <= s.overlap_fraction <= 1.0
    d = s.as_dict()
    assert set(d) >= {"rounds", "swap_in_s", "stall_s", "overlap_fraction"}


# --------------------------------------------------------------------------- #
# Streamed k-way merge stage: prefetch overlap on disk tiers                   #
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("driver", DRIVERS)
@pytest.mark.parametrize("tier", DISK_TIERS)
@pytest.mark.parametrize("P", (1, 2))
def test_streamed_merge_overlap_and_bit_identity(driver, tier, P, tmp_path):
    """PSRS's merge stage runs with stream=True: on disk tiers the next
    round's buckets are read through the block API while the in-flight
    round merges, under every driver (not just "async").  The result must
    stay bit-identical to the device reference, and the streamed-stage
    counters must record the overlap."""
    rng = np.random.default_rng(23)
    n, v, k = 2048, 8, 2
    data = rng.integers(-2**31, 2**31 - 1, size=n, dtype=np.int32)
    ref = psrs_sort(data, v=v, k=k)
    out, pems = psrs_sort(
        data, v=v, k=k, P=P, driver=driver, tier=tier,
        backing_path=str(tmp_path / f"bk_{driver}_{tier}_{P}.bin"),
        return_pems=True)
    np.testing.assert_array_equal(out, ref)
    assert len(pems.shard_stats) == P
    for st in pems.shard_stats:
        # v/(P·k) = 4/P resident rounds in the merge superstep → at least
        # rounds−1 ahead-of-need submissions per shard.
        assert st.merge_prefetch_events >= (v // (P * k)) - 1 > 0
        assert st.merge_stall_s >= 0.0
    merged = pems.merged_shard_stats()
    assert merged.merge_prefetch_events == sum(
        st.merge_prefetch_events for st in pems.shard_stats)
    assert "merge_prefetch_events" in merged.as_dict()


@pytest.mark.parametrize("io_driver", ("buffered", "odirect", "mmap"))
@pytest.mark.parametrize("P", (1, 2))
def test_streamed_merge_file_engines_bit_identical(io_driver, P, tmp_path):
    """tier="file" across the three I/O engines × P ∈ {1, 2}: the streamed
    merge must report overlap events and stay bit-identical."""
    rng = np.random.default_rng(29)
    data = rng.integers(-2**31, 2**31 - 1, size=2048, dtype=np.int32)
    ref = np.sort(data)
    out, pems = psrs_sort(
        data, v=8, k=2, P=P, tier="file", io_driver=io_driver,
        backing_path=str(tmp_path / f"eng_{io_driver}_{P}.bin"),
        return_pems=True)
    np.testing.assert_array_equal(out, ref)
    assert pems.merged_shard_stats().merge_prefetch_events > 0
    assert pems.merged_shard_ledger().syscall_read_bytes > 0


# --------------------------------------------------------------------------- #
# Checkpoint → restore of a memmap-backed store, resuming PSRS                 #
# --------------------------------------------------------------------------- #

def test_checkpoint_restore_memmap_resumes_psrs(tmp_path):
    rng = np.random.default_rng(3)
    n, v, k = 2048, 8, 2
    data = rng.integers(-1000, 1000, size=n,
                        dtype=np.int32).reshape(v, n // v)
    want = np.sort(data.reshape(-1))

    def finish(res, cnt):
        return np.concatenate([res[i, :cnt[i, 0]] for i in range(v)])

    # Run the first five stages (through `partition`), checkpoint the store.
    pems1, load1, steps1, _ = psrs_plan(
        v, n // v, k=k, driver="async", tier="memmap",
        backing_path=str(tmp_path / "a.bin"))
    st1 = load1(data)
    for _, step in steps1[:5]:
        st1 = step(st1)
    mgr = CheckpointManager(str(tmp_path / "ckpt"), keep=2)
    mgr.save(5, {"store": st1.backing.arr}, blocking=True)

    # "New process": fresh plan, fresh zeroed backing file, restore in place
    # (never materializing v·mu on device), run the remaining stages.
    pems2, _, steps2, extract2 = psrs_plan(
        v, n // v, k=k, driver="async", tier="memmap",
        backing_path=str(tmp_path / "b.bin"))
    st2 = pems2.init()
    step_got = mgr.restore_latest(like={"store": st2.backing.arr})
    assert step_got is not None and step_got[0] == 5
    assert step_got[1]["store"] is st2.backing.arr   # filled in place
    for _, step in steps2[5:]:
        st2 = step(st2)
    res, cnt, oflow = extract2(st2)
    assert not np.asarray(oflow).any()
    np.testing.assert_array_equal(finish(res, cnt), want)

    # The checkpoint array file must itself be a streamable .npy (memmap
    # flag recorded in the manifest).
    import json
    d = str(tmp_path / "ckpt" / "step_000000000005")
    manifest = json.load(open(os.path.join(d, "manifest.json")))
    assert manifest["arrays"][0]["memmap"] is True
