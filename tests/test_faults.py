"""Fault-injection + integrity layer: FaultSpec grammar, deterministic
injection through the driver proxy, engine retry/backoff policy,
permanent-error propagation, drain(timeout=) diagnostics, per-block CRC
sidecars (round-trip, flip-a-byte, adopt/recompute), and the checkpoint
manifest's chunk CRCs."""

import os
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FileBacking, MemmapBacking, PemsConfig
from repro.io import (
    CHECK_BLOCK,
    ChecksumSidecar,
    FaultSpec,
    FaultyFile,
    IntegrityError,
    IOEngine,
    TRANSIENT_ERRNOS,
    ensure_file_size,
    open_file,
)
from repro.io.checksum import span_plan


# --------------------------------------------------------------------------- #
# FaultSpec grammar                                                            #
# --------------------------------------------------------------------------- #

def test_fault_spec_parses_full_grammar():
    fs = FaultSpec.parse(
        "seed=7; eio@p0.02:x2; lat@w0-3:0.003; torn@w44:0.25;"
        "enospc@b0-4095; kill@r12; eio@*")
    assert fs.seed == 7
    eio_p, lat, torn, enospc, kill, eio_star = fs.clauses
    assert (eio_p.kind, eio_p.prob, eio_p.param) == ("eio", 0.02, 2.0)
    assert (lat.op, lat.lo, lat.hi, lat.param) == ("write", 0, 3, 0.003)
    assert (torn.op, torn.lo, torn.hi, torn.param) == ("write", 44, 44, 0.25)
    assert (enospc.byte_lo, enospc.byte_hi) == (0, 4095)
    assert (kill.op, kill.lo) == ("read", 12)
    assert eio_star.lo is None and eio_star.prob is None
    assert FaultSpec.parse(None).clauses == []
    assert FaultSpec.parse("").clauses == []


@pytest.mark.parametrize("bad, match", [
    ("flip@*", "kind"),
    ("eio", "expected"),
    ("eio@z9", "selector"),
    ("eio@p1.5", "probability"),
    ("eio@*:k3", "eio param"),
    ("torn@w0:0.0", "torn fraction"),
    ("torn@w0:1.5", "torn fraction"),
    ("lat@*:-1", "negative latency"),
    ("enospc@*:0.5", "no parameter"),
    ("kill@*:now", "no parameter"),
    ("seed=abc", "seed"),
])
def test_fault_spec_rejects_bad_clauses(bad, match):
    with pytest.raises(ValueError, match=match):
        FaultSpec.parse(bad)


def test_config_validates_fault_and_integrity_knobs(tmp_path):
    with pytest.raises(ValueError, match="unknown io_driver"):
        PemsConfig(v=4, k=2, tier="file", io_driver="faulty:uring")
    with pytest.raises(ValueError, match="faulty"):
        PemsConfig(v=4, k=2, tier="file", io_driver="buffered",
                   fault_spec="eio@*")
    with pytest.raises(ValueError, match="kind"):
        PemsConfig(v=4, k=2, tier="file", io_driver="faulty:buffered",
                   fault_spec="flip@*")
    with pytest.raises(ValueError, match="checksums"):
        PemsConfig(v=4, k=2, tier="host", checksums=True)
    with pytest.raises(ValueError, match="io_retries"):
        PemsConfig(v=4, k=2, io_retries=-1)
    with pytest.raises(ValueError, match="io_backoff_s"):
        PemsConfig(v=4, k=2, io_backoff_s=-0.1)
    # Valid: faulty driver resolves, parses its spec at construction.
    cfg = PemsConfig(v=4, k=2, tier="file", io_driver="faulty:buffered",
                     fault_spec="seed=3;eio@p0.01",
                     backing_path=str(tmp_path / "c.bin"))
    assert cfg.io_driver == "faulty:buffered"
    with pytest.raises(ValueError, match="fault_spec"):
        open_file(str(tmp_path / "x.bin"), 4096, "buffered",
                  fault_spec="eio@*")


# --------------------------------------------------------------------------- #
# Engine retry policy over injected faults                                     #
# --------------------------------------------------------------------------- #

def _faulty_engine(tmp_path, spec, retries=2, name="f.bin", **kw):
    f = open_file(str(tmp_path / name), 1 << 16, "faulty:buffered",
                  fault_spec=spec)
    return f, IOEngine(f, queue_depth=1, retries=retries, **kw)


def test_transient_eio_absorbed_by_retries(tmp_path):
    f, eng = _faulty_engine(tmp_path, "eio@w0:x2")
    try:
        data = np.full(4096, 7, np.uint8)
        eng.submit_write(0, data).wait()
        out = np.empty(4096, np.uint8)
        eng.submit_read(0, out).wait()
        np.testing.assert_array_equal(out, data)
        assert f.injected["eio"] == 2
        assert eng.retries == 2
        assert eng.permanent_errors == 0
        assert eng.backoff_s > 0.0
        assert f.driver == "faulty:buffered"
    finally:
        eng.close()


def test_retry_backoff_is_deterministic(tmp_path):
    walls = []
    for name in ("a.bin", "b.bin"):
        f, eng = _faulty_engine(tmp_path, "eio@w0:x2;eio@w5:x1", name=name)
        try:
            for i in range(8):
                eng.submit_write(i * 4096, np.full(4096, i, np.uint8)).wait()
            walls.append((eng.retries, eng.backoff_s, f.injected["eio"]))
        finally:
            eng.close()
    assert walls[0] == walls[1]
    assert walls[0][0] == 3 and walls[0][1] > 0.0


def test_exhausted_retries_become_permanent(tmp_path):
    f, eng = _faulty_engine(tmp_path, "eio@w0:x5", retries=2)
    try:
        req = eng.submit_write(0, np.zeros(4096, np.uint8))
        with pytest.raises(OSError) as ei:
            req.wait()
        assert ei.value.errno in TRANSIENT_ERRNOS   # EIO, just out of budget
        assert eng.retries == 2                     # budget was spent
        assert eng.permanent_errors == 1
        assert f.injected["eio"] == 3               # 1 try + 2 retries
        with pytest.raises(OSError):
            eng.drain()                 # the completion still reaps as error
    finally:
        eng.close()


def test_enospc_is_never_retried(tmp_path):
    f, eng = _faulty_engine(tmp_path, "enospc@w*", retries=3)
    try:
        req = eng.submit_write(0, np.zeros(4096, np.uint8))
        with pytest.raises(OSError, match="ENOSPC|injected"):
            req.wait()
        assert eng.retries == 0                     # permanent: no retry
        assert eng.permanent_errors == 1
        assert f.injected["enospc"] == 1
        with pytest.raises(OSError):
            eng.drain()
    finally:
        eng.close()


def test_injected_latency_is_counted_and_survived(tmp_path):
    f, eng = _faulty_engine(tmp_path, "lat@*:0.001")
    try:
        data = np.full(4096, 3, np.uint8)
        eng.submit_write(0, data).wait()
        out = np.empty(4096, np.uint8)
        eng.submit_read(0, out).wait()
        np.testing.assert_array_equal(out, data)
        assert f.injected["lat"] == 2
        assert eng.permanent_errors == 0
    finally:
        eng.close()


def test_drain_timeout_names_the_stuck_requests(tmp_path):
    f = open_file(str(tmp_path / "t.bin"), 1 << 16, "buffered")
    eng = IOEngine(f, queue_depth=2)
    try:
        eng._gate.clear()               # hold workers: requests never finish
        eng.submit_write(8192, np.zeros(4096, np.uint8))
        with pytest.raises(TimeoutError) as ei:
            eng.drain(timeout=0.2)
        msg = str(ei.value)
        assert "t.bin" in msg and "8192" in msg and "in flight" in msg
        assert eng.in_flight == 1       # still in flight, not dropped
        eng._gate.set()
        eng.drain()                     # and still completes once released
        assert eng.in_flight == 0
    finally:
        eng._gate.set()
        eng.close()


def test_torn_write_is_silent_at_the_driver(tmp_path):
    f = open_file(str(tmp_path / "torn.bin"), 1 << 14, "faulty:buffered",
                  fault_spec="torn@w0:0.25")
    try:
        data = np.full(8192, 0xAB, np.uint8)
        assert f.pwrite(0, data) == 8192            # reports full success
        out = np.empty(8192, np.uint8)
        f.pread_into(0, out)
        assert (out[:2048] == 0xAB).all()           # only the prefix landed
        assert (out[2048:] == 0).all()
        assert f.injected["torn"] == 1
    finally:
        f.close()


# --------------------------------------------------------------------------- #
# Checksum sidecar: geometry, round-trip, torn-write detection                 #
# --------------------------------------------------------------------------- #

def test_span_plan_geometry():
    chk, rowbytes = 4096, 3 * 4096
    # One range covering a whole segment: one span, nothing partial.
    assert span_plan([(0, 4096)], chk, rowbytes) == [(0, 0, [])]
    # Straddling two segments, both partially.
    assert span_plan([(2048, 6144)], chk, rowbytes) == [(0, 1, [0, 1])]
    # Two ranges that jointly cover segment 0 exactly.
    assert span_plan([(0, 2048), (2048, 4096)], chk, rowbytes) == [(0, 0, [])]
    # Disjoint segments -> separate spans.
    assert span_plan([(0, 4096), (8192, 12288)], chk, rowbytes) == [
        (0, 0, []), (2, 2, [])]
    # Tail segment shorter than chk counts as covered when fully written.
    assert span_plan([(8192, 10000)], chk, 10000) == [(2, 2, [])]
    assert span_plan([], chk, rowbytes) == []


@pytest.mark.parametrize("tier", ("memmap", "file"))
def test_checksum_round_trip_and_flip_a_byte(tmp_path, tier):
    v, words = 8, 2048                  # rowbytes = 8192: 1 segment/row
    path = str(tmp_path / "c.bin")
    cls = MemmapBacking if tier == "memmap" else FileBacking
    b = cls(v, words, path, checksum=True)
    try:
        rng = np.random.default_rng(2)
        want = rng.integers(0, 2 ** 32, (v, words), dtype=np.uint32)
        b.write_block(0, v, want)
        np.testing.assert_array_equal(b.read_block(0, v), want)
        cols = np.arange(4, 9)
        patch = np.full((v, 5), 17, np.uint32)
        b.write_block(0, v, patch, cols=cols)
        want[:, 4:9] = patch
        np.testing.assert_array_equal(b.read_block(0, v, cols=cols), patch)
        b.flush()
        # Corrupt one byte in the middle of row 3 behind the store's back.
        with open(path, "r+b") as f:
            off = 3 * words * 4 + 100
            f.seek(off)
            byte = f.read(1)
            f.seek(off)
            f.write(bytes([byte[0] ^ 0xFF]))
        with pytest.raises(IntegrityError) as ei:
            b.read_block(0, v)
        assert ei.value.row == 3 and ei.value.seg == 100 // CHECK_BLOCK
        assert os.path.exists(path + ".crc")
    finally:
        if tier == "file":
            b.close()


def test_checksummed_file_backing_detects_injected_torn_write(tmp_path):
    """The acceptance wiring: a silent torn write under the engine is caught
    by the sidecar at the next read — not silently merged."""
    v, words = 4, 2048
    path = str(tmp_path / "torn.bin")
    b = FileBacking(v, words, path, io_driver="faulty:buffered",
                    fault_spec="torn@wb0-8191:0.3", checksum=True)
    try:
        data = np.arange(v * words, dtype=np.uint32).reshape(v, words)
        b.write_block(0, 1, data[:1])   # row 0's write is torn, silently
        b.write_block(1, v, data[1:])   # outside the fault's byte range
        with pytest.raises(IntegrityError) as ei:
            b.read_block(0, v)
        assert ei.value.row == 0
        # Rows beyond the fault's byte range still verify.
        np.testing.assert_array_equal(b.read_block(1, v), data[1:])
        # recompute_checksums blesses what's actually on disk (resume path:
        # the recovery layer restores/reruns the torn rows afterwards).
        b.recompute_checksums()
        b.read_block(0, v)              # no longer raises
    finally:
        b.close()


def test_sidecar_adopts_existing_file_and_reuses_itself(tmp_path):
    v, words = 4, 1024
    path = str(tmp_path / "a.bin")
    plain = FileBacking(v, words, path)
    want = np.arange(v * words, dtype=np.uint32).reshape(v, words)
    plain.write_block(0, v, want)
    plain.flush()
    plain.close()
    # Adoption: checksums recomputed from the existing contents.
    b1 = FileBacking(v, words, path, checksum=True)
    np.testing.assert_array_equal(b1.read_block(0, v), want)
    b1.flush()
    b1.close()
    # Reuse: the sidecar header matches, so it is reopened, not reseeded.
    sc = ChecksumSidecar(path, v, words * 4)
    assert not sc.fresh
    # A fresh backing file seeds zero-CRCs that verify zero reads.
    b2 = MemmapBacking(v, words, str(tmp_path / "z.bin"), checksum=True)
    assert (b2.read_block(0, v) == 0).all()


def test_sidecar_refuses_unknown_algorithm(tmp_path):
    path = str(tmp_path / "alg.bin")
    MemmapBacking(2, 1024, path, checksum=True).flush()
    with open(path + ".crc", "r+b") as f:
        f.seek(12)                      # algo field of the header
        f.write(np.uint32(7).tobytes())  # algorithm id nobody has
    with pytest.raises(IntegrityError, match="written with"):
        ChecksumSidecar(path, 2, 4096)


def test_ensure_file_size_error_is_actionable(tmp_path):
    missing = str(tmp_path / "no" / "such" / "dir" / "f.bin")
    with pytest.raises(OSError, match="cannot create/extend"):
        ensure_file_size(missing, 4096)


# --------------------------------------------------------------------------- #
# Checkpoint manifest chunk CRCs                                               #
# --------------------------------------------------------------------------- #

def test_checkpoint_detects_flipped_byte_and_falls_back(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    import json
    m = CheckpointManager(str(tmp_path / "ckpt"), keep=5)
    state = {"w": np.arange(4096, dtype=np.float32).reshape(64, 64)}
    m.save(1, state, blocking=True)
    m.save(2, {"w": state["w"] * 2}, blocking=True)
    man = json.load(open(str(tmp_path / "ckpt" / "step_000000000002" /
                             "manifest.json")))
    assert man["version"] == 2 and man["arrays"][0]["chunk_crcs"]
    shard = str(tmp_path / "ckpt" / "step_000000000002" / "arr_00000.npy")
    with open(shard, "r+b") as f:
        f.seek(500)
        byte = f.read(1)
        f.seek(500)
        f.write(bytes([byte[0] ^ 0xFF]))
    with pytest.raises(IOError, match="checksum mismatch"):
        m.restore(2, like=state)
    step, got = m.restore_latest(like=state)    # falls back to step 1
    assert step == 1
    np.testing.assert_array_equal(np.asarray(got["w"]), state["w"])
