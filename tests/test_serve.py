"""Serving engine tests: batched generation, greedy determinism, and
generation consistency with teacher-forced logits."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import Model
from repro.serve import ServeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2-1.5b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_generate_shapes_and_determinism(setup):
    cfg, model, params = setup
    eng = ServeEngine(model, params, max_seq=64)
    prompts = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab, (3, 8)), jnp.int32)
    out1 = eng.generate(prompts, steps=6)
    out2 = eng.generate(prompts, steps=6)
    assert out1.shape == (3, 6)
    np.testing.assert_array_equal(out1, out2)       # greedy = deterministic
    assert (out1 >= 0).all() and (out1 < cfg.vocab).all()


def test_generate_matches_teacher_forcing(setup):
    """Greedy generation re-fed through the full forward must reproduce the
    same argmax chain (cache correctness end-to-end)."""
    cfg, model, params = setup
    eng = ServeEngine(model, params, max_seq=64)
    prompts = jnp.asarray(
        np.random.default_rng(1).integers(0, cfg.vocab, (2, 8)), jnp.int32)
    gen = eng.generate(prompts, steps=5)
    full = jnp.concatenate([prompts, jnp.asarray(gen)], axis=1)
    logits, _ = model.logits(params, {"tokens": full})
    for t in range(5):
        pos = 8 + t - 1
        want = np.asarray(jnp.argmax(logits[:, pos], axis=-1))
        np.testing.assert_array_equal(gen[:, t], want)


def test_generate_ssm_and_hybrid():
    for name in ("mamba2-130m", "recurrentgemma-2b"):
        cfg = get_config(name).smoke()
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        eng = ServeEngine(model, params, max_seq=64)
        prompts = jnp.asarray(
            np.random.default_rng(2).integers(0, cfg.vocab, (2, 6)),
            jnp.int32)
        gen = eng.generate(prompts, steps=4)
        full = jnp.concatenate([prompts, jnp.asarray(gen)], axis=1)
        logits, _ = model.logits(params, {"tokens": full})
        for t in range(4):
            want = np.asarray(jnp.argmax(logits[:, 6 + t - 1], axis=-1))
            np.testing.assert_array_equal(gen[:, t], want)


def test_sampled_generation_valid(setup):
    cfg, model, params = setup
    eng = ServeEngine(model, params, max_seq=64)
    prompts = jnp.zeros((2, 4), jnp.int32)
    out = eng.generate(prompts, steps=4, temperature=1.0,
                       rng=jax.random.PRNGKey(3))
    assert out.shape == (2, 4)
    assert (out >= 0).all() and (out < cfg.vocab).all()
