"""Substrate tests: optimizer (f32 + int8 moments), microbatch accumulation,
gradient compression, checkpoint fault tolerance, data determinism, sharding
rules, and a small end-to-end training run with loss decrease."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import DataConfig, synthetic_batches
from repro.data.pipeline import synthetic_batch
from repro.models import Model
from repro.optim import OptConfig, adamw_init, adamw_update, cosine_schedule
from repro.train import TrainConfig, init_train_state, make_train_step


# --------------------------------------------------------------------------- #
# Optimizer                                                                    #
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("quant", [False, True])
def test_adamw_converges_quadratic(quant):
    cfg = OptConfig(lr=0.1, weight_decay=0.0, quantize_moments=quant, block=8)
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    state = adamw_init(params, cfg)
    target = jnp.array([1.0, 1.0, 1.0])
    for _ in range(200):
        g = {"w": 2 * (params["w"] - target)}
        params, state, _ = adamw_update(params, g, state, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)


def test_adamw_int8_state_is_int8():
    cfg = OptConfig(quantize_moments=True, block=16)
    params = {"w": jnp.zeros((40,), jnp.float32)}
    state = adamw_init(params, cfg)
    assert state["m"]["w"]["q"].dtype == jnp.int8
    # 4x smaller than f32 moments (plus small scale overhead).
    f32_bytes = 40 * 4
    q_bytes = state["m"]["w"]["q"].size
    assert q_bytes <= f32_bytes // 2


def test_cosine_schedule_shape():
    s0 = float(cosine_schedule(0, warmup=10, total=100))
    s_w = float(cosine_schedule(10, warmup=10, total=100))
    s_end = float(cosine_schedule(100, warmup=10, total=100))
    assert s0 == 0.0 and abs(s_w - 1.0) < 1e-6 and 0.05 < s_end < 0.15


# --------------------------------------------------------------------------- #
# Train step                                                                   #
# --------------------------------------------------------------------------- #

def _tiny_setup(microbatches=1, grad_compress=False):
    cfg = get_config("qwen2-1.5b").smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tcfg = TrainConfig(
        opt=OptConfig(lr=1e-3, weight_decay=0.0),
        microbatches=microbatches, warmup_steps=2, total_steps=100,
        grad_compress=grad_compress)
    state = init_train_state(params, tcfg)
    step = jax.jit(make_train_step(model, tcfg))
    data = DataConfig(seq_len=16, global_batch=4, vocab=cfg.vocab, seed=0)
    return model, state, step, data


def test_train_loss_decreases():
    # Train on one fixed batch: fresh hash-random tokens every step have no
    # learnable structure (loss would sit at the irreducible ln(vocab)), but
    # memorising a batch still exercises the full model/optimizer/step path.
    _, state, step, data = _tiny_setup()
    batch = synthetic_batch(data, 0)
    losses = []
    for i in range(30):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses[::10]
    assert np.isfinite(losses).all()


def test_microbatch_equals_full_batch_grads():
    """4 microbatches of 1 == 1 batch of 4 (same update direction)."""
    _, state1, step1, data = _tiny_setup(microbatches=1)
    _, state4, step4, _ = _tiny_setup(microbatches=4)
    batch = synthetic_batch(data, 0)
    s1, m1 = step1(state1, batch)
    s4, m4 = step4(state4, batch)
    # Same loss and nearly identical parameters after one update.
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-4
    diffs = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        s1.params, s4.params)
    assert max(jax.tree.leaves(diffs)) < 5e-3


def test_grad_compression_still_converges():
    _, state, step, data = _tiny_setup(grad_compress=True)
    batch = synthetic_batch(data, 0)
    losses = []
    for i in range(30):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses[::10]


# --------------------------------------------------------------------------- #
# Checkpointing / fault tolerance                                              #
# --------------------------------------------------------------------------- #

def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
             "b": {"c": jnp.int32(7)}}
    mgr.save(3, state)
    got = mgr.restore_latest(like=state)
    assert got is not None
    step, restored = got
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(state["a"]))
    assert int(restored["b"]["c"]) == 7


def test_checkpoint_keep_k_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"x": jnp.zeros(4)}
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": jnp.full(4, float(s))})
    dirs = sorted(os.listdir(tmp_path))
    assert dirs == ["step_000000000003", "step_000000000004"]
    step, restored = mgr.restore_latest(like=state)
    assert step == 4 and float(restored["x"][0]) == 4.0


def test_checkpoint_survives_torn_write(tmp_path):
    """A crash mid-save (manifest missing / corrupt) must fall back to the
    previous checkpoint."""
    mgr = CheckpointManager(str(tmp_path), keep=5)
    state = {"x": jnp.zeros(4)}
    mgr.save(1, {"x": jnp.full(4, 1.0)})
    mgr.save(2, {"x": jnp.full(4, 2.0)})
    # Simulate a torn checkpoint at step 3: directory exists, manifest bad.
    d = tmp_path / "step_000000000003"
    d.mkdir()
    (d / "manifest.json").write_text("{ corrupt")
    step, restored = mgr.restore_latest(like=state)
    assert step == 2 and float(restored["x"][0]) == 2.0


def test_crash_resume_training_continuity(tmp_path):
    """Kill training mid-run; resume from checkpoint; the loss trajectory
    continues (bitwise: same data stream via step counter)."""
    _, state, step_fn, data = _tiny_setup()
    mgr = CheckpointManager(str(tmp_path), keep=3)

    losses_a = []
    for i, batch in zip(range(10), synthetic_batches(data)):
        state, m = step_fn(state, batch)
        losses_a.append(float(m["loss"]))
        if i == 4:
            mgr.save(i + 1, state)
    # "crash" — rebuild everything from disk
    _, fresh, step_fn2, _ = _tiny_setup()
    got = mgr.restore_latest(like=fresh)
    assert got is not None
    start, state2 = got
    assert start == 5
    losses_b = []
    for i, batch in zip(range(start, 10),
                        synthetic_batches(data, start_step=start)):
        state2, m = step_fn2(state2, batch)
        losses_b.append(float(m["loss"]))
    np.testing.assert_allclose(losses_a[start:], losses_b, rtol=1e-5)


def test_checkpoint_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"x": jnp.arange(10_000, dtype=jnp.float32)}
    mgr.save(1, state, blocking=False)
    mgr.wait()
    got = mgr.restore_latest(like=state)
    assert got is not None and got[0] == 1


# --------------------------------------------------------------------------- #
# Data pipeline                                                                #
# --------------------------------------------------------------------------- #

def test_data_deterministic_and_step_addressable():
    d = DataConfig(seq_len=32, global_batch=4, vocab=1000, seed=7)
    b1 = synthetic_batch(d, 5)
    b2 = synthetic_batch(d, 5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = synthetic_batch(d, 6)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    assert int(b1["tokens"].max()) < 1000 and int(b1["tokens"].min()) >= 0


def test_data_vlm_and_audio_fronts():
    d = DataConfig(seq_len=16, global_batch=2, vocab=100, frontend="patches",
                   n_frontend_tokens=4, d_model=8)
    b = synthetic_batch(d, 0)
    assert b["tokens"].shape == (2, 12) and b["patches"].shape == (2, 4, 8)
    d2 = DataConfig(seq_len=16, global_batch=2, vocab=100, frontend="frames",
                    d_model=8)
    b2 = synthetic_batch(d2, 0)
    assert b2["frames"].shape == (2, 16, 8) and b2["labels"].shape == (2, 16)


# --------------------------------------------------------------------------- #
# Sharding rules (structure only; device placement exercised by the dry-run)   #
# --------------------------------------------------------------------------- #

def test_param_pspecs_cover_model():
    from repro.distributed.sharding import ShardingRules, param_pspecs
    from jax.sharding import PartitionSpec as P
    cfg = get_config("kimi-k2-1t-a32b").smoke()
    model = Model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    from repro.launch.mesh import make_mesh_auto
    mesh = make_mesh_auto((1, 1), ("data", "model"))
    rules = ShardingRules(mesh=mesh)
    specs = param_pspecs(rules, params)
    flat_p = jax.tree.leaves(params)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for p, s in zip(flat_p, flat_s):
        assert len(s) <= len(p.shape)
