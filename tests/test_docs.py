"""Docs satellites: public-API docstrings and the docs consistency gate."""

import os
import subprocess
import sys

import pytest

from repro.core import Pems, PemsConfig
from repro.core.backing import FileBacking, ShardedBacking, make_backing
from repro.io.engine import IOEngine
from repro.pems_apps.psrs import psrs_run_recoverable, psrs_sort

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("obj", [
    psrs_sort, psrs_run_recoverable, Pems.alltoallv, Pems.superstep,
    PemsConfig, IOEngine, FileBacking, ShardedBacking, make_backing,
], ids=lambda o: o.__name__)
def test_public_api_has_docstring(obj):
    doc = obj.__doc__
    assert doc and len(doc.strip()) > 80, f"{obj.__name__} under-documented"


def test_docstrings_cover_sharding_and_units():
    """Spot checks: the P>1 sharding semantics and byte units the tentpole
    introduced are actually stated where users will look for them."""
    assert ".shard" in psrs_sort.__doc__            # shard file naming
    assert "shard" in psrs_run_recoverable.__doc__.lower()
    assert "bytes" in PemsConfig.__doc__            # byte-valued knob units
    assert "procs" in Pems.alltoallv.__doc__        # per-process restriction
    assert "Raises" in psrs_sort.__doc__ or "raises" in psrs_sort.__doc__
    assert "seconds" in IOEngine.__doc__            # time units
    assert "TUNING" in PemsConfig.__doc__           # pointer to the guide


def test_check_docs_gate_passes():
    """The CI docs gate (link check + PemsConfig coverage of TUNING.md)
    passes against the committed tree."""
    r = subprocess.run(
        [sys.executable, os.path.join(_ROOT, "scripts", "check_docs.py")],
        capture_output=True, text=True, timeout=60, cwd=_ROOT,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "docs OK" in r.stdout


def test_architecture_and_tuning_exist_and_are_linked():
    for name in ("ARCHITECTURE.md", "TUNING.md"):
        path = os.path.join(_ROOT, "docs", name)
        assert os.path.exists(path), name
        assert len(open(path).read()) > 2000, f"{name} is a stub"
    readme = open(os.path.join(_ROOT, "README.md")).read()
    assert "docs/ARCHITECTURE.md" in readme
    assert "docs/TUNING.md" in readme
