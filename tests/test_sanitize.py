"""Runtime sanitizer (``io_driver="sanitize:<inner>"``): planted races are
caught with the submitting stack, clean traffic reports zero findings, the
wrapper composes with ``faulty:``, and config/driver plumbing accepts the
new chain."""

import numpy as np
import pytest

from repro.core import PemsConfig
from repro.core.backing import make_backing
from repro.io import (
    IOEngine,
    SanitizingFile,
    collect_findings,
    open_file,
)


def _sanitized_engine(tmp_path, name="s.bin", **kw):
    f = open_file(str(tmp_path / name), 1 << 16, "sanitize:buffered")
    return f, IOEngine(f, queue_depth=4, **kw)


# --------------------------------------------------------------------------- #
# Planted races are detected                                                   #
# --------------------------------------------------------------------------- #

def test_mutate_while_in_flight_is_caught_with_stack(tmp_path):
    """The regression net: a deliberate mutate-after-submit is reported as
    mutate-in-flight, and the finding's stack names this test's submit."""
    f, eng = _sanitized_engine(tmp_path)
    try:
        eng._gate.clear()                 # hold the worker pre-I/O
        buf = np.zeros(256, dtype=np.uint8)
        eng.submit_write(0, buf)
        buf[:8] = 7                       # the race under test
        eng._gate.set()
        eng.drain()
    finally:
        eng.close()
    assert [x.kind for x in f.findings] == ["mutate-in-flight"]
    report = f.findings[0].format()
    assert "mutate-in-flight" in report
    assert "test_mutate_while_in_flight" in f.findings[0].stack
    assert f.format_findings() == report


def test_overlapping_unserialized_writes_are_caught(tmp_path):
    f, eng = _sanitized_engine(tmp_path)
    try:
        eng._gate.clear()
        a = np.ones(512, dtype=np.uint8)
        b = np.full(512, 2, dtype=np.uint8)
        eng.submit_write(0, a)
        eng.submit_write(256, b)          # overlaps [256, 512) of a
        eng._gate.set()
        eng.drain()
    finally:
        eng.close()
    assert [x.kind for x in f.findings] == ["overlap"]
    assert "[256, 768)" in f.findings[0].detail \
        or "overlaps" in f.findings[0].detail


def test_read_overlapping_inflight_write_is_caught(tmp_path):
    f, eng = _sanitized_engine(tmp_path)
    try:
        eng._gate.clear()
        a = np.ones(512, dtype=np.uint8)
        out = np.zeros(64, dtype=np.uint8)
        eng.submit_write(0, a)
        eng.submit_read(64, out)          # read inside the in-flight write
        eng._gate.set()
        eng.drain()
    finally:
        eng.close()
    assert [x.kind for x in f.findings] == ["overlap"]
    assert f.findings[0].op == "read"


# --------------------------------------------------------------------------- #
# Clean traffic: zero findings                                                 #
# --------------------------------------------------------------------------- #

def test_disjoint_and_sequential_traffic_is_clean(tmp_path):
    f, eng = _sanitized_engine(tmp_path)
    try:
        bufs = [np.full(128, i, dtype=np.uint8) for i in range(8)]
        for i, b in enumerate(bufs):
            eng.submit_write(i * 128, b)          # disjoint ranges
        eng.drain()
        out = np.zeros(1024, dtype=np.uint8)
        eng.submit_read(0, out)
        eng.drain()
        # Same range again, but strictly after the drain barrier.
        eng.submit_write(0, np.arange(128, dtype=np.uint8))
        eng.drain()
    finally:
        eng.close()
    assert f.findings == []
    assert f.tracked == 10                        # the sanitizer was live


def test_file_backing_round_trip_is_clean(tmp_path):
    bk = make_backing("file", 16, 4, str(tmp_path / "bk.bin"),
                      io_driver="sanitize:buffered")
    try:
        data = np.arange(64, dtype=np.uint32).reshape(16, 4)
        bk.write_block(0, 16, data)
        np.testing.assert_array_equal(bk.read_block(0, 16), data)
    finally:
        bk.close()
    assert collect_findings(bk) == []
    assert bk.file.tracked > 0


def test_sharded_backing_keeps_sanitizer_per_shard(tmp_path):
    bk = make_backing("file", 8, 4, str(tmp_path / "sh.bin"), P=2,
                      io_driver="sanitize:buffered")
    try:
        data = np.arange(32, dtype=np.uint32).reshape(8, 4)
        bk.write_block(0, 8, data)
        np.testing.assert_array_equal(bk.read_block(0, 8), data)
        assert all(isinstance(s.file, SanitizingFile) for s in bk.shards)
    finally:
        bk.close()
    assert collect_findings(bk) == []


# --------------------------------------------------------------------------- #
# Plumbing: chain parsing, composition, validation                             #
# --------------------------------------------------------------------------- #

def test_wrapper_properties_delegate(tmp_path):
    f = open_file(str(tmp_path / "p.bin"), 4096, "sanitize:buffered")
    assert f.driver == "sanitize:buffered"
    assert f.align == f.inner.align and f.path == f.inner.path
    f.close()


def test_composes_with_faulty(tmp_path):
    """sanitize:faulty:buffered — the sanitizer sits above the injector;
    an injected EIO flows through retries while tracking stays exact."""
    f = open_file(str(tmp_path / "c.bin"), 1 << 16,
                  "sanitize:faulty:buffered", fault_spec="eio@w0")
    assert f.driver == "sanitize:faulty:buffered"
    eng = IOEngine(f, queue_depth=1, retries=2)
    try:
        eng.submit_write(0, np.ones(64, dtype=np.uint8))
        eng.drain()
    finally:
        eng.close()
    assert f.inner.injected["eio"] == 1
    assert f.findings == [] and f.tracked == 1

    cfg = PemsConfig(v=4, k=2, tier="file",
                     io_driver="sanitize:faulty:buffered",
                     fault_spec="seed=3;eio@p0.01",
                     backing_path=str(tmp_path / "cfg.bin"))
    assert cfg.io_driver == "sanitize:faulty:buffered"


def test_config_accepts_and_rejects_sanitize_chains(tmp_path):
    cfg = PemsConfig(v=4, k=2, tier="file", io_driver="sanitize:buffered",
                     backing_path=str(tmp_path / "a.bin"))
    assert cfg.io_driver == "sanitize:buffered"
    with pytest.raises(ValueError, match="unknown io_driver"):
        PemsConfig(v=4, k=2, tier="file", io_driver="sanitize:uring")
    with pytest.raises(ValueError, match="unknown io_driver"):
        PemsConfig(v=4, k=2, tier="file", io_driver="sanitize:")
    with pytest.raises(ValueError, match="fault_spec"):
        # sanitize alone does not license a fault_spec.
        PemsConfig(v=4, k=2, tier="file", io_driver="sanitize:buffered",
                   fault_spec="eio@*")
