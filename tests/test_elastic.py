"""Elastic scaling: checkpoints are mesh-shape agnostic — save under one
device topology, restore under another (subprocess with a different fake
device count), and restore with explicit shardings."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager


def test_restore_with_shardings(tmp_path):
    """Restore re-lays leaves out with the provided shardings."""
    mgr = CheckpointManager(str(tmp_path))
    state = {"w": jnp.arange(32, dtype=jnp.float32).reshape(4, 8)}
    mgr.save(1, state)
    sharding = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    got = mgr.restore_latest(like=state, shardings={"w": sharding})
    assert got is not None
    _, restored = got
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))
    assert restored["w"].sharding == sharding


_ELASTIC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import CheckpointManager

    mgr = CheckpointManager("{d}")
    from repro.launch.mesh import make_mesh_auto
    mesh = make_mesh_auto(({n},), ("data",))
    sh = NamedSharding(mesh, P("data", None))
    like = {{"w": jnp.zeros((16, 4))}}
    if {save}:
        w = jax.device_put(jnp.arange(64, dtype=jnp.float32).reshape(16, 4),
                           sh)
        mgr.save(7, {{"w": w}})
        print("SAVED", len(jax.devices()))
    else:
        step, st = mgr.restore_latest(like=like, shardings={{"w": sh}})
        assert step == 7
        np.testing.assert_array_equal(
            np.asarray(st["w"]).ravel(), np.arange(64, dtype=np.float32))
        print("RESTORED", len(jax.devices()))
""")


def _run(code):
    return subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=300,
        env={"PYTHONPATH": "src", "PATH": os.environ.get("PATH", ""),
             # Without an explicit platform, jax probes for TPUs via the
             # cloud metadata URL and stalls for minutes off-cloud.
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")},
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )


def test_elastic_restore_across_device_counts(tmp_path):
    """Save sharded over 8 'devices', restore sharded over 4 — the elastic
    rescale path a preempted fleet needs."""
    r1 = _run(_ELASTIC.format(n=8, d=tmp_path, save=True))
    assert "SAVED 8" in r1.stdout, r1.stderr[-2000:]
    r2 = _run(_ELASTIC.format(n=4, d=tmp_path, save=False))
    assert "RESTORED 4" in r2.stdout, r2.stderr[-2000:]
