"""Edge-case and negative-path coverage for the PEMS core."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ContextLayout, Pems, PemsConfig


def test_config_validation():
    with pytest.raises(ValueError):
        PemsConfig(v=8, P=3)            # v not divisible by P
    with pytest.raises(ValueError):
        PemsConfig(v=8, k=3)            # v/P not divisible by k
    with pytest.raises(ValueError):
        PemsConfig(v=8, driver="nvme")  # unknown driver


def test_config_alpha_validation():
    """The Alltoallv network chunk is validated at construction: alpha=0
    used to mean "no chunking" silently (`alpha or m`), and negative or
    > v/P values passed straight through to the chunk loop."""
    for bad in (0, -1, -8, 9, 10**6):
        with pytest.raises(ValueError, match="alpha"):
            PemsConfig(v=8, alpha=bad)
    with pytest.raises(ValueError, match="alpha"):
        PemsConfig(v=16, P=4, alpha=5)       # alpha bound is v/P, not v
    with pytest.raises(ValueError, match="integer"):
        PemsConfig(v=8, alpha=2.5)
    # Boundary values that must construct.
    assert PemsConfig(v=8, alpha=1).alpha == 1
    assert PemsConfig(v=8, alpha=8).alpha == 8
    assert PemsConfig(v=16, P=4, alpha=4).alpha == 4
    assert PemsConfig(v=8, alpha=None).alpha is None


def test_p_gt_1_requires_mesh():
    lo = ContextLayout().add("x", (4,), jnp.int32)
    with pytest.raises(ValueError):
        Pems(PemsConfig(v=8, P=2), lo)


def test_alltoallv_field_shape_validation():
    v = 4
    lo = (ContextLayout()
          .add("send", (v, 4), jnp.int32)
          .add("recv", (v, 8), jnp.int32)   # mismatched ω
          .add("bad", (3, 4), jnp.int32))
    pems = Pems(PemsConfig(v=v), lo)
    store = pems.init()
    with pytest.raises(ValueError):
        pems.alltoallv(store, "send", "recv")
    with pytest.raises(ValueError):
        pems.alltoallv(store, "bad", "bad")
    with pytest.raises(ValueError):
        pems.alltoallv(store, "send", "send", mode="quantum")


def test_reduce_rejects_noncommutative():
    lo = (ContextLayout().add("x", (2,), jnp.float32)
          .add("o", (2,), jnp.float32))
    pems = Pems(PemsConfig(v=4), lo)
    with pytest.raises(ValueError):
        pems.reduce(pems.init(), "x", "o", op="sub")


def test_ctx_update_and_k_equals_v():
    """All contexts resident at once (k = v): degenerate in-memory mode —
    the thesis' 'mem' driver observation (§9.1)."""
    v = 4
    lo = (ContextLayout().add("a", (2,), jnp.int32)
          .add("b", (2,), jnp.float32))
    pems = Pems(PemsConfig(v=v, k=v), lo)
    store = pems.init()

    def step(rho, ctx):
        return ctx.update(a=jnp.full(2, rho), b=jnp.full(2, 0.5) * rho)

    store = pems.superstep(store, step)
    np.testing.assert_array_equal(np.asarray(store.field("a"))[:, 0],
                                  np.arange(v))
    np.testing.assert_allclose(np.asarray(store.field("b"))[:, 1],
                               np.arange(v) * 0.5)


def test_superstep_deterministic_recovery():
    """Fault-tolerance invariant: re-executing a superstep from the stored
    contexts is bit-identical — a failed round can always be replayed."""
    v = 8
    lo = ContextLayout().add("x", (16,), jnp.float32)
    pems = Pems(PemsConfig(v=v, k=2), lo)
    store = pems.init(lambda rho: {"x": jnp.full(16, rho, jnp.float32)})
    snapshot = store.data

    def step(rho, ctx):
        x = ctx.get("x")
        return ctx.set("x", jnp.sin(x) * 2.0 + rho)

    out1 = pems.superstep(store, step).data
    from repro.core import ContextStore
    out2 = pems.superstep(ContextStore(lo, snapshot), step).data
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_ledger_merge():
    from repro.core import IOLedger
    a, b = IOLedger(), IOLedger()
    a.add_swap_in(100, 10)
    a.require_disk(500)
    b.add_msg_direct(50, 10)
    b.require_disk(300)
    m = a.merge(b)
    assert m.swap_in == 100 and m.msg_direct == 50
    assert m.disk_space == 500          # max, not sum
    assert m.num_ios == a.num_ios + b.num_ios


def test_multipod_artifacts_refreshed():
    """The three hillclimb cells were re-measured on the multi-pod mesh with
    post-optimization code: their artifacts must be coherent."""
    import json
    import os
    cells = ["kimi-k2-1t-a32b__train_4k", "arctic-480b__train_4k",
             "qwen3-14b__prefill_32k"]
    art = "artifacts/dryrun"
    if not os.path.isdir(art):
        pytest.skip("artifacts not generated here")
    for c in cells:
        fn = os.path.join(art, f"{c}__multi.json")
        if not os.path.exists(fn):
            pytest.skip("multi-pod artifacts not present")
        d = json.load(open(fn))
        assert "error" not in d
        assert d["mesh"].get("pod") == 2
        single = json.load(open(os.path.join(art, f"{c}__single.json")))
        # Multi-pod halves (or better) the per-device footprint for these
        # memory-pressured cells.
        assert (d["memory"]["per_device_bytes"]
                <= single["memory"]["per_device_bytes"] * 1.05)
