"""Optional-hypothesis shim.

``hypothesis`` is an extra (``pip install -e .[test]``), not a hard
dependency.  Test modules import ``given``/``settings``/``st`` from here
instead of from ``hypothesis`` directly: when hypothesis is installed the
real decorators are re-exported and the property tests run; when it is
absent the decorators mark the property tests as skipped, so collection
still succeeds and the example-based tests in the same module run.
"""

from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _Strategies:
        """Stub strategy factory: every strategy is a no-op placeholder
        (the decorated test is skipped before the values would be drawn)."""

        def __getattr__(self, name):
            def strategy(*_args, **_kwargs):
                return None

            return strategy

    st = _Strategies()

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
