"""pems-lint acceptance: every rule fires on its seeded fixture (rule id +
file:line) and stays silent on the clean twin, suppressions work in all
three styles, the baseline round-trips, and the committed tree is clean
with an empty baseline."""

import json
import os
import subprocess
import sys

import pytest

from repro.lint import ALL_RULES, lint_paths, load_baseline
from repro.lint.engine import save_baseline

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_FIXTURES = os.path.join(_ROOT, "tests", "lint_fixtures")


def _lint(*paths, rules=ALL_RULES):
    return lint_paths([os.path.join(_FIXTURES, p) for p in paths], rules)


# --------------------------------------------------------------------------- #
# One rule per seeded fixture, zero on the clean twin                          #
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("fixture, rule, line", [
    ("block_api_bad.py", "block-api-only", 8),
    ("durability_bad.py", "atomic-durability", 12),
    ("ledger_bad.py", "ledger-balance", 8),
    ("ledger_double_bad.py", "ledger-balance", 7),
    ("trace_bad.py", "trace-purity", 6),
    ("submit_bad.py", "submit-then-mutate", 7),
    ("trace_balance_bad.py", "trace-balance", 6),
])
def test_seeded_fixture_fires_exactly_one_rule(fixture, rule, line):
    findings, suppressed = _lint(fixture)
    assert len(findings) == 1, [f.format() for f in findings]
    f = findings[0]
    assert (f.rule, f.line) == (rule, line), f.format()
    assert f.path.endswith(fixture)
    assert suppressed == 0
    # The human format carries rule id and file:line for CI logs.
    assert f"{f.line}:" in f.format() and rule in f.format()


@pytest.mark.parametrize("fixture", [
    "block_api_clean.py", "durability_clean.py", "ledger_clean.py",
    "trace_clean.py", "submit_clean.py", "trace_balance_clean.py",
])
def test_clean_twin_fires_nothing(fixture):
    findings, _ = _lint(fixture)
    assert findings == [], [f.format() for f in findings]


# --------------------------------------------------------------------------- #
# Suppressions                                                                 #
# --------------------------------------------------------------------------- #

def test_suppression_styles_all_work():
    """Same-line, comment-line-above, and disable=all each silence their
    violation; stripping the comments proves they were load-bearing."""
    findings, suppressed = _lint("suppressed.py")
    assert findings == [] and suppressed == 3

    from repro.lint.engine import FileContext
    src = open(os.path.join(_FIXTURES, "suppressed.py")).read()
    stripped = "\n".join(ln.split("# pems-lint:")[0].rstrip() or "#"
                         for ln in src.splitlines())
    ctx = FileContext("suppressed_stripped.py", stripped)
    raw = [f for rule in ALL_RULES for f in rule.check(ctx)]
    assert len(raw) == 3


def test_suppression_requires_matching_rule(tmp_path):
    """A disable= comment naming a different rule does not silence."""
    p = tmp_path / "wrong.py"
    p.write_text("import numpy as np\n\n\ndef f(path):\n"
                 "    return np.memmap(path)"
                 "  # pems-lint: disable=ledger-balance\n")
    findings, suppressed = lint_paths([str(p)], ALL_RULES)
    assert [f.rule for f in findings] == ["block-api-only"]
    assert suppressed == 0


# --------------------------------------------------------------------------- #
# Baseline round-trip                                                          #
# --------------------------------------------------------------------------- #

def test_baseline_round_trip(tmp_path):
    findings, _ = _lint("block_api_bad.py", "durability_bad.py")
    assert len(findings) == 2
    bl = str(tmp_path / "baseline.json")
    save_baseline(bl, findings)
    keys = load_baseline(bl)
    assert keys == {f.key() for f in findings}
    # Everything baselined -> nothing new.
    assert [f for f in findings if f.key() not in keys] == []
    # A fresh violation is still new against the old baseline.
    more, _ = _lint("block_api_bad.py", "durability_bad.py",
                    "ledger_bad.py")
    new = [f for f in more if f.key() not in keys]
    assert [f.rule for f in new] == ["ledger-balance"]


def test_missing_baseline_is_empty(tmp_path):
    assert load_baseline(str(tmp_path / "nope.json")) == set()
    assert load_baseline(None) == set()


# --------------------------------------------------------------------------- #
# CLI + the committed tree                                                     #
# --------------------------------------------------------------------------- #

def _run_cli(*args):
    return subprocess.run(
        [sys.executable, os.path.join(_ROOT, "scripts", "pems_lint.py"),
         *args],
        capture_output=True, text=True, timeout=120, cwd=_ROOT)


def test_cli_exit_codes_and_json():
    bad = os.path.join("tests", "lint_fixtures", "block_api_bad.py")
    r = _run_cli(bad)
    assert r.returncode == 1
    assert "block-api-only" in r.stdout and "block_api_bad.py:8" in r.stdout
    r = _run_cli(bad, "--json")
    report = json.loads(r.stdout)
    assert report["findings"][0]["rule"] == "block-api-only"
    r = _run_cli("--list-rules")
    assert r.returncode == 0
    for rule in ALL_RULES:
        assert rule.name in r.stdout


def test_committed_tree_is_clean_with_empty_baseline():
    """The acceptance gate: src + scripts lint clean, and the committed
    baseline file is empty (no grandfathered findings)."""
    r = _run_cli("src", "scripts", "--baseline", "pems_lint_baseline.json")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "0 finding(s)" in r.stdout
    with open(os.path.join(_ROOT, "pems_lint_baseline.json")) as f:
        assert json.load(f) == []
