"""Unit tests for the roofline report/table generation and the analytic
memory floor (no compiles needed)."""


from repro.roofline.analyze import analytic_bytes_floor
from repro.roofline.report import dryrun_table, roofline_table


def _row(**kw):
    base = {
        "arch": "test-arch", "shape": "train_4k", "kind": "train",
        "multi_pod": False, "compile_s": 1.0,
        "mesh": {"data": 16, "model": 16},
        "memory": {"per_device_bytes": 8e9, "fits_hbm": True,
                   "argument_bytes": 1, "output_bytes": 1, "temp_bytes": 1,
                   "alias_bytes": 0},
        "cost": {"flops_per_device": 1e12, "bytes_per_device": 1e11},
        "collectives": {"bytes_by_kind": {"all-gather": 1e9, "all-reduce": 0,
                                          "reduce-scatter": 0,
                                          "all-to-all": 0,
                                          "collective-permute": 0},
                        "count_by_kind": {}, "weighted_bytes": 1e9},
        "roofline": {"compute_s": 0.005, "memory_s": 0.12,
                     "collective_s": 0.02, "dominant": "memory",
                     "roofline_fraction": 0.04,
                     "step_lower_bound_s": 0.12},
        "useful_flop_ratio": 0.5,
        "optimizer": "adamw-f32",
    }
    base.update(kw)
    return base


def test_dryrun_table_rows():
    rows = [_row(), {"arch": "x", "shape": "long_500k", "skipped": "reason"}]
    t = dryrun_table(rows, "single")
    assert "test-arch" in t and "SKIP: reason" in t
    assert "8.00" in t          # bytes/dev GB


def test_roofline_table_prefers_calibrated():
    d = _row()
    d["calibrated"] = {
        "flops": 5e13, "bytes": 2e12, "coll": 5e10,
        "roofline": {"compute_s": 0.25, "memory_s": 2.4,
                     "collective_s": 1.0, "dominant": "memory",
                     "roofline_fraction": 0.105,
                     "step_lower_bound_s": 2.4},
        "useful_flop_ratio": 0.4, "memory_floor_s": 0.5,
        "roofline_fraction_optimistic": 0.25,
    }
    t = roofline_table([d])
    assert "0.25" in t and "0.105" in t and "0.400" in t


def test_analytic_floor_train_scales_sanely():
    common = dict(n_params=int(1.5e9), n_active=int(1.5e9), n_layers=28,
                  d_model=1536, vocab=151936, tokens=256 * 4096, n_mb=8,
                  n_chips=256)
    b = analytic_bytes_floor("train", **common)
    # At minimum: params touched several times -> order GBs per device.
    assert 1e8 < b < 1e12
    # int8 moments shrink the floor.
    b8 = analytic_bytes_floor("train", **dict(common, opt_bytes_per_param=4))
    assert b8 < b
    # decode floor is dominated by param + cache streaming.
    bd = analytic_bytes_floor("decode", n_params=int(1.5e9),
                              n_active=int(1.5e9), n_layers=28, d_model=1536,
                              vocab=151936, tokens=128, n_mb=1, n_chips=256,
                              cache_bytes=int(20e9))
    assert bd > 0
