"""Fixture: fires trace-purity exactly once (.item() host sync inside a
stage function passed to superstep)."""


def local_total(rho, ctx):
    total = ctx.get("x").sum().item()
    return ctx.set("total", total)


def run(pems, store):
    return pems.superstep(store, local_total, reads=["x"],
                          writes=["total"])
