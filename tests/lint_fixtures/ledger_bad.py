"""Fixture: fires ledger-balance exactly once (a direct read_block with no
accounting call anywhere in the function)."""


def scan(backing, v):
    total = 0
    for r0 in range(0, v, 4):
        total += int(backing.read_block(r0, r0 + 4).sum())
    return total
