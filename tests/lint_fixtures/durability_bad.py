"""Fixture: fires atomic-durability exactly once (rename with no fsync
anywhere before it in the function)."""

import json
import os


def save_state(path, obj):
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f)
    os.replace(tmp, path)
