"""Clean twin of trace_balance_bad: begin/end paired in-scope, the span()
context manager, and a bare cross-method end (ignored by the rule)."""


def run_round(self, r):
    self.tracer.begin(f"round:{r}", tid="rounds")
    ok = self.compute(r)
    self.tracer.end(f"round:{r}", tid="rounds")
    return ok


def run_spanned(tracer, fn):
    with tracer.span("work", tid="main"):
        return fn()


def mark_completed(self, stage):
    # The matching begin lives in another method; a bare end is clean.
    self.tracer.end(f"in_progress:{stage}", tid="recovery")
