"""Fixture: one violation per suppression style — all suppressed, so the
file lints clean (the suppression round-trip test also re-lints it with
suppressions stripped to prove they were load-bearing)."""

import numpy as np


def load_raw_same_line(path):
    return np.memmap(path, mode="r")  # pems-lint: disable=block-api-only


def load_raw_line_above(path):
    # pems-lint: disable=block-api-only
    return np.memmap(path, mode="r")


def load_raw_disable_all(path):
    return np.memmap(path, mode="r")  # pems-lint: disable=all
