"""Clean twin of durability_bad: temp + fsync + atomic rename — no
findings."""

import json
import os


def save_state(path, obj):
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
