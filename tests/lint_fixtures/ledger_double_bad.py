"""Fixture: fires ledger-balance exactly once (manual add_disk_read next
to a self-accounting store accessor — the same bytes billed twice)."""


def scan(store, ledger, rho, rowbytes):
    vals = store.field_rows("keys", rho, rho + 1)
    ledger.add_disk_read(rowbytes)
    return vals
