"""Clean twin of block_api_bad: the same data reached through the
self-accounting store accessor and a text-mode open — no findings."""


def load_via_store(store, rho):
    return store.field_rows("keys", rho, rho + 1)


def read_report(path):
    with open(path) as f:
        return f.read()
