"""Clean twin of trace_bad: the stage function stays traced end to end —
no findings."""


def local_total(rho, ctx):
    return ctx.set("total", ctx.get("x").sum())


def run(pems, store):
    return pems.superstep(store, local_total, reads=["x"],
                          writes=["total"])
