"""Clean twin of submit_bad: the mutation waits for the drain, and a
chunked submit loop over disjoint slices stays clean."""


def writeback(engine, buf):
    engine.submit_write(0, buf)
    engine.drain()
    buf[0] = 1


def chunked_read(engine, flat, nbytes, chunk):
    reqs = []
    for off in range(0, nbytes, chunk):
        reqs.append(engine.submit_read(off, flat[off:off + chunk]))
    engine.wait(reqs)
    return flat
