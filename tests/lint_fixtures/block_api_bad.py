"""Fixture: fires block-api-only exactly once (raw np.memmap outside the
io layer)."""

import numpy as np


def load_raw(path):
    return np.memmap(path, dtype=np.uint8, mode="r")
