"""Fixture: fires submit-then-mutate exactly once (buffer stored to while
its write is still in flight)."""


def writeback(engine, buf):
    engine.submit_write(0, buf)
    buf[0] = 1
    engine.drain()
