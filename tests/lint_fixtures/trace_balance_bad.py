"""Fixture: fires trace-balance exactly once (a tracer.begin whose end is
only reachable on the early-return path, so the scope leaks the span)."""


def run_round(self, r):
    self.tracer.begin(f"round:{r}", tid="rounds")
    if self.compute(r):
        return True
    self.tracer.begin("retry", tid="rounds")
    self.compute(r)
    self.tracer.end("retry", tid="rounds")
    return False
