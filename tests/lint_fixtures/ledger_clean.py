"""Clean twin of ledger_bad/ledger_double_bad: a direct block transfer
paired with accounting, and a self-accounting accessor left alone."""


def scan_direct(backing, ledger, v, rowbytes):
    total = 0
    for r0 in range(0, v, 4):
        total += int(backing.read_block(r0, r0 + 4).sum())
        ledger.add_disk_read(4 * rowbytes)
    return total


def scan_store(store, rho):
    return store.field_rows("keys", rho, rho + 1)
