"""Chaos matrix: PSRS on the file tier under injected faults — seeded EIO
bursts absorbed by engine retries, torn writes healed by the superstep
recovery protocol, and genuine ``kill -9`` (subprocess) at every stage with
bit-identical resume.  The acceptance harness for the fault-injection +
crash-recovery layer."""

import json
import os
import shutil
import signal
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.pems_apps import psrs_sort

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
        "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")}

# One fixed dataset per child: a resumed run must reproduce the exact bytes
# an uninterrupted run would have produced.
_CHILD = textwrap.dedent("""
    import sys
    import numpy as np
    from repro.pems_apps import psrs_run_recoverable

    state_dir, io_driver, kind, stage, fault_spec = sys.argv[1:6]
    rng = np.random.default_rng(17)
    data = rng.integers(-2**31, 2**31 - 1, size=1024, dtype=np.int32)
    out = psrs_run_recoverable(
        data, v=4, k=2, state_dir=state_dir,
        io_driver=("faulty:" + io_driver) if fault_spec else io_driver,
        fault_spec=fault_spec or None,
        io_queue_depth=4,
        crash_in_stage=int(stage) if kind == "in" else None,
        crash_after_stage=int(stage) if kind == "after" else None,
    )
    np.testing.assert_array_equal(out, np.sort(data))
    print("CHAOS_OK")
""")

_N_STAGES = 8       # "load" + the seven psrs_plan stages


def _run_child(state_dir, io_driver, kind="none", stage=0, fault_spec=""):
    return subprocess.run(
        [sys.executable, "-c", _CHILD, str(state_dir), io_driver,
         kind, str(stage), fault_spec],
        capture_output=True, text=True, timeout=600, env=_ENV, cwd=_REPO)


def _assert_killed(r):
    assert r.returncode == -signal.SIGKILL, (r.returncode, r.stderr[-3000:])


def _assert_ok(r):
    assert "CHAOS_OK" in r.stdout, (r.returncode, r.stderr[-3000:])


# --------------------------------------------------------------------------- #
# kill -9 at every stage, one state_dir: each child resumes the previous      #
# child's progress, dies one stage later, and the final child completes       #
# bit-identically.                                                            #
# --------------------------------------------------------------------------- #

def test_kill9_mid_stage_every_stage_then_resume(tmp_path):
    sd = str(tmp_path / "state")
    for stage in range(_N_STAGES):
        _assert_killed(_run_child(sd, "buffered", kind="in", stage=stage))
        assert os.path.exists(os.path.join(sd, "cursor.json"))
    _assert_ok(_run_child(sd, "buffered"))
    # A re-run against the finished state_dir is a pure no-op resume.
    _assert_ok(_run_child(sd, "buffered"))


@pytest.mark.parametrize("io_driver, kind, stages", [
    ("odirect", "after", (0, 3, 6)),
    ("odirect", "in", (1, 5)),
    ("mmap", "in", (0, 4, 7)),
    ("mmap", "after", (2, 6)),
])
def test_kill9_matrix_other_drivers(tmp_path, io_driver, kind, stages):
    sd = str(tmp_path / "state")
    for stage in stages:
        _assert_killed(_run_child(sd, io_driver, kind=kind, stage=stage))
    _assert_ok(_run_child(sd, io_driver))


def test_torn_write_healed_by_resume(tmp_path):
    """A silent torn write inside the in-progress stage, then kill -9 before
    the stage commits: the resume recomputes the sidecar over what actually
    hit the disk, reruns the stage, and the final output is bit-identical."""
    sd = str(tmp_path / "state")
    r = _run_child(sd, "buffered", kind="in", stage=0,
                   fault_spec="torn@wb0-4095:0.5")
    _assert_killed(r)
    _assert_ok(_run_child(sd, "buffered"))


# --------------------------------------------------------------------------- #
# Seeded transient-fault matrix: EIO bursts + latency spikes across all       #
# three io drivers, absorbed in-process by the engine's bounded retries.      #
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("io_driver", ("buffered", "odirect", "mmap"))
def test_seeded_eio_bursts_absorbed_by_retries(tmp_path, io_driver):
    rng = np.random.default_rng(23)
    data = rng.integers(-2**31, 2**31 - 1, size=2048, dtype=np.int32)
    out, pems = psrs_sort(
        data, v=8, k=2, driver="async", tier="file",
        io_driver=f"faulty:{io_driver}",
        fault_spec="seed=5;eio@p0.03:x2;lat@p0.02:0.001",
        io_retries=4, io_queue_depth=4,
        backing_path=str(tmp_path / "ctx.bin"), return_pems=True)
    np.testing.assert_array_equal(out, np.sort(data))
    assert pems.backing.file.injected["eio"] > 0      # faults really fired
    s = pems.tier_stats
    assert s.retries >= pems.backing.file.injected["eio"] > 0
    assert s.permanent_errors == 0
    assert s.backoff_s > 0.0


# --------------------------------------------------------------------------- #
# Checkpoint crash-mid-save: a leftover .tmp staging dir (the crash window)   #
# is never mistaken for a checkpoint, and the prior step stays restorable.    #
# --------------------------------------------------------------------------- #

def test_checkpoint_crash_mid_save_keeps_prior_step(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    d = str(tmp_path / "ckpt")
    m = CheckpointManager(d, keep=5)
    state = {"w": np.arange(256, dtype=np.float32)}
    m.save(7, state, blocking=True)

    # Simulated crash mid-save of step 8: shard written, manifest torn.
    tmp = os.path.join(d, "step_000000000008.tmp")
    shutil.copytree(os.path.join(d, "step_000000000007"), tmp)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        f.write('{"step": 8, "arrays": [')          # torn JSON
    got = m.restore_latest(like=state)
    assert got is not None and got[0] == 7
    np.testing.assert_array_equal(np.asarray(got[1]["w"]), state["w"])

    # A fresh save of the same step cleans the leftover staging dir and
    # commits atomically; the manifest carries chunk CRCs (version 2).
    m.save(8, {"w": state["w"] + 1}, blocking=True)
    got = m.restore_latest(like=state)
    assert got[0] == 8
    man = json.load(open(os.path.join(d, "step_000000000008",
                                      "manifest.json")))
    assert man["version"] == 2
    assert all(a["chunk_crcs"] for a in man["arrays"])


# --------------------------------------------------------------------------- #
# Sanitizer matrix: the full PSRS driver×P sweep on the file tier under       #
# io_driver="sanitize:buffered" — bit-identical results and zero in-flight    #
# race findings.  The regression net for the shared-engine scheduler work:   #
# any future overlap/mutate-while-in-flight bug on the hot path fails here    #
# with the submitting stack in the report.                                    #
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("driver", ("explicit", "sliced", "async"))
@pytest.mark.parametrize("P", (1, 2))
def test_psrs_matrix_under_sanitizer_is_race_free(tmp_path, driver, P):
    from repro.io import collect_findings

    rng = np.random.default_rng(29)
    data = rng.integers(-2**31, 2**31 - 1, size=1024, dtype=np.int32)
    out, pems = psrs_sort(
        data, v=4, k=2, driver=driver, P=P, tier="file",
        io_driver="sanitize:buffered", io_queue_depth=4,
        backing_path=str(tmp_path / "ctx.bin"), return_pems=True)
    np.testing.assert_array_equal(out, np.sort(data))
    findings = collect_findings(pems.backing)
    assert findings == [], "\n".join(f.format() for f in findings)
    shards = getattr(pems.backing, "shards", None) or [pems.backing]
    assert all(s.file.tracked > 0 for s in shards)   # sanitizer was live


def test_sanitizer_composes_with_faulty_in_psrs(tmp_path):
    """sanitize:faulty:buffered end to end: injected transient EIO is
    absorbed by retries while the sanitizer confirms the engine's own
    traffic stays race-free even on retried requests."""
    rng = np.random.default_rng(31)
    data = rng.integers(-2**31, 2**31 - 1, size=1024, dtype=np.int32)
    out, pems = psrs_sort(
        data, v=4, k=2, driver="async", tier="file",
        io_driver="sanitize:faulty:buffered",
        fault_spec="seed=5;eio@p0.03:x2", io_retries=4, io_queue_depth=4,
        backing_path=str(tmp_path / "ctx.bin"), return_pems=True)
    from repro.io import collect_findings
    np.testing.assert_array_equal(out, np.sort(data))
    assert pems.backing.file.inner.injected["eio"] > 0
    assert collect_findings(pems.backing) == []
