"""repro.io engine + tier="file" backing: submission/completion semantics,
O_DIRECT alignment and stat-level accounting, create-or-reuse backing files
(crash consistency: flush-then-reopen round-trips), config validation, and
PSRS bit-identity across the io-driver × executor-driver matrix (subprocess
pinned against the device-tier reference)."""

import os
import subprocess
import sys
import textwrap
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ContextLayout,
    FileBacking,
    MemmapBacking,
    Pems,
    PemsConfig,
    WORD,
)
from repro.io import ALIGN, IOEngine, open_file
from repro.pems_apps import psrs_sort

IO_DRIVERS = ("buffered", "odirect", "mmap")


# --------------------------------------------------------------------------- #
# Engine semantics                                                             #
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("driver", IO_DRIVERS)
def test_engine_round_trip(tmp_path, driver):
    size = 1 << 18
    path = str(tmp_path / f"{driver}.bin")
    f = open_file(path, size, driver)
    eng = IOEngine(f, queue_depth=4)
    rng = np.random.default_rng(0)
    data = rng.integers(0, 256, size, dtype=np.uint8)
    try:
        eng.wait([eng.submit_write(o, data[o:o + 8192])
                  for o in range(0, size, 8192)])
        eng.fsync()
        out = np.empty(size, np.uint8)
        eng.wait([eng.submit_read(o, out[o:o + 8192])
                  for o in range(0, size, 8192)])
        np.testing.assert_array_equal(out, data)
        assert eng.max_queue_depth <= 4
        assert eng.fsyncs == 1
        assert eng.syscall_read_bytes >= size
        assert eng.syscall_write_bytes >= size
    finally:
        eng.close()


def test_engine_drain_leaves_no_inflight(tmp_path):
    path = str(tmp_path / "d.bin")
    eng = IOEngine(open_file(path, 1 << 16, "buffered"), queue_depth=8)
    try:
        buf = np.zeros(1 << 16, np.uint8)
        for o in range(0, 1 << 16, 4096):
            eng.submit_write(o, buf[o:o + 4096])
        eng.drain()
        assert eng.in_flight == 0
        assert eng.poll() == []      # drain reaped every completion
    finally:
        eng.close()


def test_engine_bounded_queue_blocks_submitter(tmp_path):
    """The submission queue is genuinely bounded: a submit into a full queue
    blocks (measured as queue_stall_s) until a slot frees."""
    path = str(tmp_path / "q.bin")
    eng = IOEngine(open_file(path, 1 << 16, "buffered"), queue_depth=2)
    try:
        eng._gate.clear()            # hold workers: requests stay in flight
        buf = np.zeros(4096, np.uint8)
        eng.submit_write(0, buf)
        eng.submit_write(4096, buf)
        assert eng.in_flight == 2

        submitted = threading.Event()

        def third():
            eng.submit_write(8192, buf)
            submitted.set()

        t = threading.Thread(target=third)
        t.start()
        time.sleep(0.1)
        assert not submitted.is_set()    # blocked on the full queue
        eng._gate.set()
        t.join(timeout=5)
        assert submitted.is_set()
        eng.drain()
        assert eng.queue_stall_s > 0.0
        assert eng.max_queue_depth <= 2
    finally:
        eng._gate.set()
        eng.close()


def test_engine_rw_overlap_counter(tmp_path):
    """Deterministic both-directions-in-flight detection: with a write held
    in flight, submitting a read records an rw-overlap event."""
    path = str(tmp_path / "rw.bin")
    eng = IOEngine(open_file(path, 1 << 16, "buffered"), queue_depth=4)
    try:
        eng._gate.clear()
        eng.submit_write(0, np.zeros(4096, np.uint8))
        out = np.empty(4096, np.uint8)
        eng.submit_read(8192, out)
        assert eng.rw_overlap_events == 1
        eng._gate.set()
        eng.drain()
    finally:
        eng._gate.set()
        eng.close()


def test_engine_error_propagates(tmp_path):
    path = str(tmp_path / "err.bin")
    eng = IOEngine(open_file(path, 1 << 16, "buffered"), queue_depth=2)
    try:
        eng.submit_read(-5, np.empty(4096, np.uint8))   # invalid offset
        with pytest.raises(OSError):
            eng.drain()
        assert eng.in_flight == 0
    finally:
        eng.close()


# --------------------------------------------------------------------------- #
# O_DIRECT: alignment, read-modify-write, stat-level accounting               #
# --------------------------------------------------------------------------- #

def _odirect_engine(tmp_path, size):
    path = str(tmp_path / "od.bin")
    f = open_file(path, size, "odirect")
    return path, f, IOEngine(f, queue_depth=4)


def test_odirect_unaligned_rmw_preserves_neighbours(tmp_path):
    size = 4 * ALIGN
    path, f, eng = _odirect_engine(tmp_path, size)
    try:
        base = np.arange(size, dtype=np.uint32).view(np.uint8)[:size].copy()
        eng.submit_write(0, base).wait()
        patch = np.full(100, 0xAB, np.uint8)
        eng.submit_write(ALIGN - 50, patch).wait()   # straddles a block edge
        out = np.empty(size, np.uint8)
        eng.submit_read(0, out).wait()
        want = base.copy()
        want[ALIGN - 50:ALIGN + 50] = patch
        np.testing.assert_array_equal(out, want)
        if not f.fallback:
            # Every syscall the driver issued was whole-block.
            assert eng.syscall_write_bytes % ALIGN == 0
            assert eng.syscall_read_bytes % ALIGN == 0
    finally:
        eng.close()


def test_odirect_concurrent_boundary_writes_serialised(tmp_path):
    """Adjacent unaligned writes share boundary blocks; the engine must
    serialise their read-modify-write so no update is lost."""
    n, span = 64, 1000                      # 1000 % 4096 != 0: shared blocks
    size = n * span
    path, f, eng = _odirect_engine(tmp_path, size)
    try:
        rng = np.random.default_rng(3)
        data = rng.integers(0, 256, size, dtype=np.uint8)
        eng.wait([eng.submit_write(i * span, data[i * span:(i + 1) * span])
                  for i in range(n)])
        out = np.empty(size, np.uint8)
        eng.submit_read(0, out).wait()
        np.testing.assert_array_equal(out, data)
    finally:
        eng.close()


def test_odirect_syscall_bytes_vs_stat(tmp_path):
    """Satellite: the ledger's syscall-level byte counts line up with what
    ``os.stat`` says the file occupies.  Written-once aligned file: the
    syscall writes equal the file size exactly; on filesystems that report
    real block allocation the allocated delta matches too (filesystems that
    preallocate on truncate — delta 0 — are detected and the comparison
    falls back to st_size)."""
    size = 32 * ALIGN
    path = str(tmp_path / "stat.bin")
    f = open_file(path, size, "odirect")
    blocks_before = os.stat(path).st_blocks * 512
    eng = IOEngine(f, queue_depth=8)
    try:
        rng = np.random.default_rng(5)
        data = rng.integers(0, 256, size, dtype=np.uint8)  # incompressible
        for o in range(0, size, 4 * ALIGN):
            eng.submit_write(o, data[o:o + 4 * ALIGN])
        eng.fsync()
        st = os.stat(path)
        assert st.st_size == size
        assert eng.syscall_write_bytes == size     # each block written once
        if not f.fallback:
            assert eng.syscall_write_bytes % ALIGN == 0
        # Block-level occupancy covers every byte the ledger claims was
        # written.  On a sparse-truncating fs the *delta* equals the write
        # volume exactly; a preallocating fs (blocks_before > 0) already
        # charged the blocks at truncate, so occupancy is the comparison.
        allocated = st.st_blocks * 512
        assert allocated >= size
        if blocks_before == 0:
            assert allocated - blocks_before >= eng.syscall_write_bytes
    finally:
        eng.close()


def test_odirect_fallback_is_documented(tmp_path):
    """Where the fs refuses O_DIRECT the driver must warn and keep working
    (buffered); where it accepts, no warning.  Either way the bytes land."""
    import warnings
    path = str(tmp_path / "fb.bin")
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        f = open_file(path, ALIGN, "odirect")
    if f.fallback:
        assert any("O_DIRECT" in str(w.message) for w in caught)
        assert f.align == 1
    else:
        assert not any("O_DIRECT" in str(w.message) for w in caught)
        assert f.align == ALIGN
    eng = IOEngine(f, queue_depth=1)
    try:
        eng.submit_write(0, np.full(ALIGN, 7, np.uint8)).wait()
        out = np.empty(ALIGN, np.uint8)
        eng.submit_read(0, out).wait()
        assert (out == 7).all()
    finally:
        eng.close()


# --------------------------------------------------------------------------- #
# Backing files: create-or-reuse, flush-then-reopen round-trips               #
# --------------------------------------------------------------------------- #

def test_memmap_backing_reopen_preserves_contents(tmp_path):
    """Regression: MemmapBacking used to open caller paths with "wb" and
    truncate — a resume against a populated backing file was silently
    zeroed."""
    path = str(tmp_path / "ctx.bin")
    v, words = 4, 16
    b1 = MemmapBacking(v, words, path)
    b1.arr[:] = np.arange(v * words, dtype=np.uint32).reshape(v, words)
    b1.flush()
    del b1
    b2 = MemmapBacking(v, words, path)
    np.testing.assert_array_equal(
        b2.arr, np.arange(v * words, dtype=np.uint32).reshape(v, words))
    # Too-small file is extended, not truncated: old bytes survive.
    b3 = MemmapBacking(v + 2, words, path)
    np.testing.assert_array_equal(
        b3.arr[:v], np.arange(v * words, dtype=np.uint32).reshape(v, words))
    assert (b3.arr[v:] == 0).all()


@pytest.mark.parametrize("io_driver", IO_DRIVERS)
def test_file_backing_reopen_preserves_contents(tmp_path, io_driver):
    path = str(tmp_path / "ctx.bin")
    v, words = 4, 16
    b1 = FileBacking(v, words, path, io_driver=io_driver)
    want = np.arange(v * words, dtype=np.uint32).reshape(v, words)
    b1.write_block(0, v, want)
    b1.flush()
    b1.close()
    b2 = FileBacking(v, words, path, io_driver=io_driver)
    np.testing.assert_array_equal(b2.read_block(0, v), want)
    b2.close()


@pytest.mark.parametrize("tier", ("memmap", "file"))
def test_flush_reopen_round_trip_through_store(tmp_path, tier):
    """Crash consistency: flush() then reopen on a fresh executor sees the
    exact bytes (the backing file is the single source of truth)."""
    path = str(tmp_path / "store.bin")
    lo = ContextLayout().add("x", (8,), jnp.int32)
    v = 4
    rng = np.random.default_rng(9)
    want = rng.integers(-1000, 1000, (v, 8)).astype(np.int32)

    pems1 = Pems(PemsConfig(v=v, k=2, tier=tier, backing_path=path), lo)
    st1 = pems1.init().with_field("x", want)
    st1.flush()
    if tier == "file":
        assert st1.backing.engine.in_flight == 0
        st1.backing.close()

    lo2 = ContextLayout().add("x", (8,), jnp.int32)
    pems2 = Pems(PemsConfig(v=v, k=2, tier=tier, backing_path=path), lo2)
    st2 = pems2.init()
    np.testing.assert_array_equal(np.asarray(st2.field("x")), want)


# --------------------------------------------------------------------------- #
# Config validation                                                            #
# --------------------------------------------------------------------------- #

def test_config_validates_tier_and_io_knobs_at_construction():
    lo = ContextLayout().add("x", (4,), jnp.int32)
    with pytest.raises(ValueError, match="unknown tier"):
        PemsConfig(v=4, k=2, tier="ssd")
    with pytest.raises(ValueError, match="unknown io_driver"):
        PemsConfig(v=4, k=2, tier="file", io_driver="uring")
    with pytest.raises(ValueError, match="requires tier='file'"):
        PemsConfig(v=4, k=2, tier="memmap", io_driver="odirect")
    with pytest.raises(ValueError, match="io_queue_depth"):
        PemsConfig(v=4, k=2, tier="file", io_queue_depth=0)
    # The init-time tier override is validated as early as the config's.
    pems = Pems(PemsConfig(v=4, k=2), lo)
    with pytest.raises(ValueError, match="unknown tier"):
        pems.init(tier="ssd")
    # Defaults resolve: file tier without io_driver means buffered.
    assert PemsConfig(v=4, k=2, tier="file").io_driver == "buffered"
    assert PemsConfig(v=4, k=2).io_driver is None


# --------------------------------------------------------------------------- #
# Ledger: requested vs syscall bytes on the file tier                          #
# --------------------------------------------------------------------------- #

def test_file_tier_ledger_counts_live_bytes(tmp_path):
    """The file tier self-accounts exactly like memmap: disk bytes = the
    live words each round touches; the syscall counters sit on top (equal
    for buffered, block-inflated for odirect)."""
    v, k, capacity = 8, 2, 64
    lo = (ContextLayout(capacity_words=capacity)
          .add("a", (8,), jnp.int32)
          .add("tmp", (16,), jnp.int32)
          .add("b", (8,), jnp.int32))
    lo.drop("tmp")                      # live hole: runs split around it
    path = str(tmp_path / "ctx.bin")
    pems = Pems(PemsConfig(v=v, k=k, tier="file", backing_path=path,
                           io_driver="buffered"), lo)
    store = pems.init()
    store = pems.superstep(
        store, lambda rho, c: c.set("a", c.get("a") + 1).set("b", c.get("b")))
    live_bytes = lo.live_words * WORD
    led = pems.ledger
    assert led.h2d_bytes == v * live_bytes
    assert led.d2h_bytes == v * live_bytes
    assert led.disk_read_bytes == v * live_bytes
    assert led.disk_write_bytes == v * live_bytes
    # Buffered pread/pwrite ask the kernel for exactly the requested bytes.
    assert led.syscall_read_bytes == led.disk_read_bytes
    assert led.syscall_write_bytes == led.disk_write_bytes
    assert pems.backing.engine.in_flight == 0
    assert os.stat(path).st_size >= v * capacity * WORD


def test_file_tier_async_drains_before_return(tmp_path):
    """After an async-driver superstep returns, no writeback may still be in
    flight (drain() guarantee) — a flush+reopen must see the final bytes."""
    rng = np.random.default_rng(1)
    data = rng.integers(-1000, 1000, size=4096, dtype=np.int32)
    out, pems = psrs_sort(data, v=8, k=2, driver="async", tier="file",
                          io_driver="buffered", return_pems=True)
    np.testing.assert_array_equal(out, np.sort(data))
    assert pems.backing.engine.in_flight == 0
    s = pems.tier_stats
    assert s.rounds > 0 and s.swap_in_s > 0
    assert s.max_queue_depth >= 1
    assert 0.0 <= s.overlap_fraction <= 1.0
    d = s.as_dict()
    assert set(d) >= {"max_queue_depth", "queue_stall_s", "fsyncs",
                      "rw_overlap_events"}


def test_file_backing_narrow_columns_odirect(tmp_path):
    """Sub-block rows with narrow column selections take the whole-row RMW
    cutover on aligned drivers: bytes still land exactly, including under
    fire-and-forget writes drained later."""
    v, words = 16, 8                    # rowbytes = 32 << ALIGN
    b = FileBacking(v, words, str(tmp_path / "n.bin"), io_driver="odirect")
    try:
        base = np.arange(v * words, dtype=np.uint32).reshape(v, words)
        b.write_block(0, v, base)
        cols = np.array([1, 2, 5])      # two runs per row
        patch = np.full((v, 3), 9999, np.uint32)
        b.write_block(0, v, patch, cols=cols, wait=False)
        b.drain()
        want = base.copy()
        want[:, cols] = patch
        np.testing.assert_array_equal(b.read_block(0, v), want)
        np.testing.assert_array_equal(b.read_block(0, v, cols=cols), patch)
    finally:
        b.close()


def test_checkpoint_noncontiguous_memmap_leaf(tmp_path):
    """A strided memmap leaf must stream (plain-copy fallback) instead of
    crashing the engine path — and a blocking save must surface nothing."""
    from repro.checkpoint.manager import CheckpointManager
    mm = np.memmap(str(tmp_path / "m.bin"), dtype=np.int32, mode="w+",
                   shape=(8, 8))
    mm[:] = np.arange(64, dtype=np.int32).reshape(8, 8)
    view = mm[:, ::2]                   # non-contiguous, still np.memmap
    mgr = CheckpointManager(str(tmp_path / "ckpt"), keep=1)
    mgr.save(1, {"s": view}, blocking=True)
    mm2 = np.memmap(str(tmp_path / "m2.bin"), dtype=np.int32, mode="w+",
                    shape=(8, 8))
    got = mgr.restore_latest(like={"s": mm2[:, ::2]})
    assert got is not None and got[0] == 1
    np.testing.assert_array_equal(np.asarray(got[1]["s"]),
                                  np.asarray(view))


# --------------------------------------------------------------------------- #
# PSRS bit-identity: io-driver × executor-driver vs the device reference       #
# (subprocess so the file-tier runs cannot share any jit/global state with     #
# the in-process reference)                                                    #
# --------------------------------------------------------------------------- #

_FILE_TIER_PSRS = textwrap.dedent("""
    import numpy as np
    from repro.pems_apps import psrs_sort

    rng = np.random.default_rng(11)
    n, v, k = 2048, 8, 2
    data = rng.integers(-2**31, 2**31 - 1, size=n, dtype=np.int32)
    ref = psrs_sort(data, v=v, k=k)          # tier="device" reference
    np.testing.assert_array_equal(ref, np.sort(data))

    for io_driver in ("buffered", "odirect", "mmap"):
        for driver in ("explicit", "sliced", "async"):
            out = psrs_sort(data, v=v, k=k, driver=driver, tier="file",
                            io_driver=io_driver, io_queue_depth=4)
            np.testing.assert_array_equal(out, ref)
    print("FILE_TIER_PSRS_OK")
""")


def test_psrs_file_tier_bit_identity_subprocess():
    r = subprocess.run(
        [sys.executable, "-c", _FILE_TIER_PSRS],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")},
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert "FILE_TIER_PSRS_OK" in r.stdout, r.stderr[-3000:]
