"""BSP application tests: PSRS, prefix sum, list ranking, Euler tour vs
oracles, including hypothesis property sweeps and driver/mode cross-checks."""

import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.pems_apps import euler_tour, list_rank, prefix_sum, psrs_sort


# --------------------------------------------------------------------------- #
# PSRS                                                                         #
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("v,k", [(4, 1), (4, 2), (8, 2), (8, 4)])
@pytest.mark.parametrize("mode", ["direct", "indirect"])
def test_psrs_sorts_random(v, k, mode):
    rng = np.random.default_rng(0)
    x = rng.integers(-2**30, 2**30, size=512, dtype=np.int32)
    out = psrs_sort(x, v=v, k=k, mode=mode)
    np.testing.assert_array_equal(out, np.sort(x))


@pytest.mark.parametrize("driver", ["explicit", "sliced", "async"])
def test_psrs_all_drivers(driver):
    rng = np.random.default_rng(1)
    x = rng.integers(0, 10**6, size=256, dtype=np.int32)
    out = psrs_sort(x, v=4, k=2, driver=driver)
    np.testing.assert_array_equal(out, np.sort(x))


def test_psrs_adversarial_presorted():
    # Pre-sorted input concentrates buckets; default cap=n/v must still work.
    x = np.arange(512, dtype=np.int32)
    np.testing.assert_array_equal(psrs_sort(x, v=8, k=2), x)
    np.testing.assert_array_equal(psrs_sort(x[::-1].copy(), v=8, k=2), x)


def test_psrs_duplicates():
    x = np.full(256, 7, np.int32)
    np.testing.assert_array_equal(psrs_sort(x, v=4), x)


@settings(max_examples=15, deadline=None)
@given(
    data=st.lists(st.integers(-2**31, 2**31 - 1), min_size=1, max_size=400),
    v_pow=st.integers(1, 3),
)
def test_psrs_property(data, v_pow):
    v = 2 ** v_pow
    x = np.asarray(data, np.int32)
    pad = (-len(x)) % v
    x = np.concatenate([x, np.full(pad, 2**31 - 1, np.int32)])
    out = psrs_sort(x, v=v)
    np.testing.assert_array_equal(out, np.sort(x))


def test_psrs_ledger_direct_beats_indirect():
    """The thesis' headline claim: PEMS2 direct delivery does less I/O than
    the PEMS1 indirect baseline for the same sort (Cor 7.1.4 / Fig 8.2-8.5)."""
    rng = np.random.default_rng(2)
    x = rng.integers(0, 2**31 - 1, size=2048, dtype=np.int32)
    _, p_dir = psrs_sort(x, v=8, k=2, mode="direct", return_pems=True)
    _, p_ind = psrs_sort(x, v=8, k=2, mode="indirect", return_pems=True)
    assert p_dir.ledger.swap_total + p_dir.ledger.msg_indirect < (
        p_ind.ledger.swap_total + p_ind.ledger.msg_indirect
    )
    assert p_dir.ledger.disk_space < p_ind.ledger.disk_space


# --------------------------------------------------------------------------- #
# Prefix sum                                                                   #
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("v,k", [(4, 1), (8, 2)])
@pytest.mark.parametrize("driver", ["explicit", "sliced"])
def test_prefix_sum(v, k, driver):
    rng = np.random.default_rng(3)
    x = rng.integers(-100, 100, size=256, dtype=np.int32)
    out = prefix_sum(x, v=v, k=k, driver=driver)
    np.testing.assert_array_equal(out, np.cumsum(x, dtype=np.int64).astype(np.int32))


@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(-1000, 1000), min_size=4, max_size=256))
def test_prefix_sum_property(data):
    v = 4
    x = np.asarray(data, np.int32)
    pad = (-len(x)) % v
    x = np.concatenate([x, np.zeros(pad, np.int32)])
    out = prefix_sum(x, v=v)
    np.testing.assert_array_equal(out, np.cumsum(x).astype(np.int32))


def test_prefix_sum_sliced_moves_less():
    x = np.ones(4096, np.int32)
    _, pe = prefix_sum(x, v=4, driver="explicit", return_pems=True)
    _, ps = prefix_sum(x, v=4, driver="sliced", return_pems=True)
    assert ps.ledger.swap_total < pe.ledger.swap_total


# --------------------------------------------------------------------------- #
# List ranking                                                                 #
# --------------------------------------------------------------------------- #

def _rank_oracle(succ):
    succ = np.asarray(succ)
    n = len(succ)
    rank = np.zeros(n, np.int64)
    for i in range(n):
        j, r = i, 0
        while succ[j] != j:
            j = succ[j]
            r += 1
            assert r <= n, "cycle"
        rank[i] = r
    return rank


def _random_lists(rng, n):
    """Random permutation split into several disjoint linked lists."""
    perm = rng.permutation(n)
    succ = np.arange(n)
    cuts = sorted(rng.choice(n, size=max(1, n // 16), replace=False))
    prev_cut = 0
    for c in list(cuts) + [n]:
        seg = perm[prev_cut:c]
        for a, b in zip(seg[:-1], seg[1:]):
            succ[a] = b
        if len(seg):
            succ[seg[-1]] = seg[-1]
        prev_cut = c
    return succ


@pytest.mark.parametrize("v,k", [(4, 1), (8, 2)])
def test_list_rank_single_chain(v, k):
    n = 64
    succ = np.arange(1, n + 1)
    succ[-1] = n - 1
    rank = list_rank(succ, v=v, k=k)
    np.testing.assert_array_equal(rank, np.arange(n - 1, -1, -1))


def test_list_rank_multiple_lists():
    rng = np.random.default_rng(4)
    succ = _random_lists(rng, 128)
    rank = list_rank(succ, v=8, k=2)
    np.testing.assert_array_equal(rank, _rank_oracle(succ))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([32, 64, 96]))
def test_list_rank_property(seed, n):
    rng = np.random.default_rng(seed)
    succ = _random_lists(rng, n)
    rank = list_rank(succ, v=4)
    np.testing.assert_array_equal(rank, _rank_oracle(succ))


# --------------------------------------------------------------------------- #
# Euler tour                                                                   #
# --------------------------------------------------------------------------- #

def _dfs_tour_oracle(parent):
    """Euler tour via DFS with children in index order; returns edge id list
    (down=2i, up=2i+1)."""
    n = len(parent)
    children = [[] for _ in range(n)]
    roots = []
    for i, p in enumerate(parent):
        if p == i:
            roots.append(i)
        else:
            children[p].append(i)
    tour = []

    def visit(u):
        for c in children[u]:
            tour.append(2 * c)
            visit(c)
            tour.append(2 * c + 1)

    for r in roots:
        visit(r)
    return tour


def _random_forest(rng, n, n_trees=1):
    parent = np.zeros(n, np.int64)
    for i in range(n_trees):
        parent[i] = i
    for i in range(n_trees, n):
        parent[i] = rng.integers(0, i)  # parents have smaller index
    return parent


@pytest.mark.parametrize("n,v", [(15, 4), (32, 4), (63, 8)])
def test_euler_tour_single_tree(n, v):
    rng = np.random.default_rng(5)
    parent = _random_forest(rng, n, 1)
    res = euler_tour(parent, v=v)
    oracle = _dfs_tour_oracle(parent)
    got = [e for e in np.argsort(-res["rank"], kind="stable")
           if res["valid"][e]]
    # Rank strictly decreases along the tour, so descending rank = tour order.
    assert got[: len(oracle)] == oracle


def test_euler_tour_forest():
    rng = np.random.default_rng(6)
    parent = _random_forest(rng, 24, 3)
    res = euler_tour(parent, v=4)
    oracle = _dfs_tour_oracle(parent)
    # Per-tree check: within each tree, descending rank equals the DFS order.
    n = len(parent)
    root_of = np.arange(n)
    for i in range(n):
        r = i
        while parent[r] != r:
            r = parent[r]
        root_of[i] = r
    for root in set(root_of):
        tree_edges = [e for e in oracle if root_of[e // 2] == root]
        got = sorted(tree_edges, key=lambda e: -res["rank"][e])
        assert got == tree_edges


def test_euler_tour_ranks_are_tour_distances():
    # Path graph 0-1-2-3: tour = d1 u1? No — path rooted at 0 with chain.
    parent = np.array([0, 0, 1, 2])
    res = euler_tour(parent, v=4)
    # Tour: d1 d2 d3 u3 u2 u1 → ranks 5..0.
    oracle = _dfs_tour_oracle(parent)
    ranks = res["rank"][oracle]
    np.testing.assert_array_equal(ranks, np.arange(len(oracle) - 1, -1, -1))
