"""The reproduction's correctness core: the executable I/O ledger must equal
the thesis' closed-form lemmas, swept over simulation parameters.

Covers Lemma 2.2.1 (PEMS1 Alltoallv), Lemma 7.1.3 + Cor 7.1.4 (EM-Alltoallv-
Seq), the exact parallel model vs analysis.pems2_alltoallv_par_io_exact,
Lemma 7.2.1 (Bcast), Lemma 7.4.2 (Reduce), Thm 2.2.3/§6.3 disk space, and the
Fig 6.2 disk-space table."""

import jax.numpy as jnp
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.core import ContextLayout, Pems, PemsConfig, analysis


def mk(v, omega, extra=64):
    return (
        ContextLayout()
        .add("pad", (extra,), jnp.int32)
        .add("send", (v, omega), jnp.int32)
        .add("recv", (v, omega), jnp.int32)
    )


def fresh(v, k, lo, **kw):
    pems = Pems(PemsConfig(v=v, k=k, **kw), lo)
    store = pems.init()
    return pems, store


# --------------------------------------------------------------------------- #
# Alltoallv volumes                                                            #
# --------------------------------------------------------------------------- #

@settings(max_examples=25, deadline=None)
@given(
    rounds=st.integers(1, 5),
    k=st.integers(1, 4),
    omega=st.integers(1, 16),
    extra=st.integers(1, 128),
)
def test_alltoallv_direct_matches_lemma_7_1_3(rounds, k, omega, extra):
    v = rounds * k
    lo = mk(v, omega, extra)
    pems, store = fresh(v, k, lo)
    base = pems.ledger.io_total
    pems.alltoallv(store, "send", "recv", mode="direct")
    got = pems.ledger.io_total - base
    want = analysis.pems2_alltoallv_seq_io(
        v, k, lo.live_bytes, omega * 4, pems.cfg.block_bytes
    )
    assert got == want


@settings(max_examples=25, deadline=None)
@given(
    rounds=st.integers(1, 5),
    k=st.integers(1, 4),
    omega=st.integers(1, 16),
    extra=st.integers(1, 128),
)
def test_alltoallv_indirect_matches_lemma_2_2_1(rounds, k, omega, extra):
    v = rounds * k
    lo = mk(v, omega, extra)
    pems, store = fresh(v, k, lo)
    base = pems.ledger.io_total
    pems.alltoallv(store, "send", "recv", mode="indirect")
    got = pems.ledger.io_total - base
    assert got == analysis.pems1_alltoallv_io(v, lo.live_bytes, omega * 4)


def test_corollary_7_1_4_improvement():
    v, k, omega = 16, 4, 8
    lo = mk(v, omega)
    mu, ob, B = lo.live_bytes, omega * 4, 4096
    p1, s1 = fresh(v, k, lo)
    p2, s2 = fresh(v, k, lo)
    b1, b2 = p1.ledger.io_total, p2.ledger.io_total
    p1.alltoallv(s1, "send", "recv", mode="indirect")
    p2.alltoallv(s2, "send", "recv", mode="direct")
    # Cor 7.1.4 compares against 3vμ for PEMS1 (the trailing swap-in of
    # Alg 2.2.1 line 8 is charged to the *next* superstep in steady state),
    # while Lemma 2.2.1 counts the full 4vμ for a standalone call.
    diff = ((p1.ledger.io_total - b1) - mu * v) - (p2.ledger.io_total - b2)
    assert diff == analysis.pems2_alltoallv_seq_improvement(v, k, mu, ob, B)


def test_parallel_io_exact_reduces_to_seq_at_P1():
    for v, k, omega, mu in [(8, 2, 16, 10_000), (16, 4, 4, 5_000)]:
        assert analysis.pems2_alltoallv_par_io_exact(
            v, 1, k, mu, omega, 4096
        ) == analysis.pems2_alltoallv_seq_io(v, k, mu, omega, 4096)


def test_parallel_ledger_matches_exact_model():
    """Direct-mode ledger with P>1 equals the exact event model (swap + msg +
    boundary; network tracked separately)."""
    v, P, k, omega = 16, 4, 2, 8
    lo = mk(v, omega)
    # Build a P>1 Pems without running anything (ledger math is trace-time and
    # mesh-independent), by faking the mesh check:
    pems = Pems.__new__(Pems)
    pems.cfg = PemsConfig(v=v, k=k, P=P)
    pems.layout = lo
    from repro.core import IOLedger
    pems.ledger = IOLedger()
    from repro.core.collectives import _ledger_alltoallv
    _ledger_alltoallv(pems, omega * 4, "direct")
    want = analysis.pems2_alltoallv_par_io_exact(
        v, P, k, lo.live_bytes, omega * 4, pems.cfg.block_bytes
    )
    assert pems.ledger.io_total == want
    # Network volume: each VP sends v − v/P remote messages.
    assert pems.ledger.network == v * (v - v // P) * omega * 4
    # Unchunked network phase (alpha=None): a single bulk all-to-all.
    assert pems.ledger.network_rounds == (
        analysis.pems2_alltoallv_par_network_rounds(v, P, k, None)
    ) == 1


def test_parallel_network_rounds_alpha_sweep():
    """The α-chunked network phase's all-to-all launch count (Alg 7.1.3)
    matches the closed form for every chunking, and bytes/IO events are
    α-independent."""
    from repro.core import IOLedger
    from repro.core.collectives import _ledger_alltoallv

    v, P, k, omega = 16, 4, 2, 8
    m = v // P
    base = None
    for alpha in (1, 2, 3, m):
        pems = Pems.__new__(Pems)
        pems.cfg = PemsConfig(v=v, k=k, P=P, alpha=alpha)
        pems.layout = mk(v, omega)
        pems.ledger = IOLedger()
        _ledger_alltoallv(pems, omega * 4, "direct")
        assert pems.ledger.network_rounds == (
            analysis.pems2_alltoallv_par_network_rounds(v, P, k, alpha)
        ) == (m // k) * -(-m // alpha)
        events = (pems.ledger.io_total, pems.ledger.network,
                  pems.ledger.num_ios, pems.ledger.supersteps)
        if base is None:
            base = events
        assert events == base


# --------------------------------------------------------------------------- #
# Rooted collectives                                                           #
# --------------------------------------------------------------------------- #

@settings(max_examples=20, deadline=None)
@given(rounds=st.integers(1, 4), k=st.integers(1, 4), n=st.integers(1, 32))
def test_bcast_matches_lemma_7_2_1(rounds, k, n):
    v = rounds * k
    lo = ContextLayout().add("x", (n,), jnp.float32)
    pems, store = fresh(v, k, lo)
    base = pems.ledger.io_total
    pems.bcast(store, "x")
    got = pems.ledger.io_total - base
    assert got == analysis.em_bcast_io(v, 1, k, lo.live_bytes, n * 4)


def test_reduce_matches_lemma_7_4_2():
    v, k, n = 8, 2, 16
    lo = (ContextLayout().add("x", (n,), jnp.float32)
          .add("out", (n,), jnp.float32))
    pems, store = fresh(v, k, lo)
    base = pems.ledger.io_total
    pems.reduce(store, "x", "out")
    assert pems.ledger.io_total - base == analysis.em_reduce_io(1, n * 4)


def test_gather_io_is_mu_plus_result():
    v, k, n = 8, 2, 4
    lo = (ContextLayout().add("x", (n,), jnp.int32)
          .add("gath", (v, n), jnp.int32))
    pems, store = fresh(v, k, lo)
    base = pems.ledger.io_total
    pems.gather(store, "x", "gath")
    # Exact form: root swap-out (μ) + the v·ω gathered result written to disk.
    # (Lemma 7.3.1 prints μ+ω with ω = the whole gathered payload.)
    assert pems.ledger.io_total - base == lo.live_bytes + v * n * 4


# --------------------------------------------------------------------------- #
# Disk space (§6.3, Fig 6.2)                                                   #
# --------------------------------------------------------------------------- #

def test_disk_space_direct_vs_indirect():
    v, k, omega = 8, 2, 4
    lo = mk(v, omega)
    p2, s2 = fresh(v, k, lo)
    p2.alltoallv(s2, "send", "recv", mode="direct")
    assert p2.ledger.disk_space == analysis.pems2_disk_space(v, 1, lo.mu_bytes)

    p1, s1 = fresh(v, k, lo)
    p1.alltoallv(s1, "send", "recv", mode="indirect")
    assert p1.ledger.disk_space == (
        analysis.pems2_disk_space(v, 1, lo.mu_bytes) + v * v * omega * 4
    )


def test_fig_6_2_disk_space_table():
    GiB = 1024**3
    rows = analysis.disk_space_table(8, 2 * GiB)
    # Fig 6.2 exact values (v/P=8, μ=2 GiB).
    want = [
        (1, 8, 16, 32, 32, 16, 16),
        (2, 16, 32, 48, 96, 16, 32),
        (4, 32, 64, 80, 320, 16, 64),
        (8, 64, 128, 144, 1152, 16, 128),
        (16, 128, 256, 272, 4352, 16, 256),
    ]
    got = [(P, v, req // GiB, p1p // GiB, p1t // GiB, p2p // GiB, p2t // GiB)
           for (P, v, req, p1p, p1t, p2p, p2t) in rows]
    assert got == want


# --------------------------------------------------------------------------- #
# Sliced driver ledger (§5.2: touched bytes only)                              #
# --------------------------------------------------------------------------- #

def test_sliced_driver_moves_fewer_bytes():
    v, k = 8, 2
    lo = (ContextLayout()
          .add("big", (4096,), jnp.float32)
          .add("small", (4,), jnp.float32))
    ex = Pems(PemsConfig(v=v, k=k, driver="explicit"), lo)
    sl = Pems(PemsConfig(v=v, k=k, driver="sliced"), lo)
    f = lambda rho, c: c.set("small", c.get("small") + 1.0)
    ex.superstep(ex.init(), f, reads=["small"], writes=["small"])
    sl.superstep(sl.init(), f, reads=["small"], writes=["small"])
    assert ex.ledger.swap_total == 2 * v * lo.live_bytes
    assert sl.ledger.swap_total == 2 * v * 4 * 4
    assert sl.ledger.swap_total < ex.ledger.swap_total // 100
