"""Allocator tests (thesis §6.6): first-fit, free with merge, reuse, and the
live-bytes accounting that lets the swap engine skip dead regions."""

import jax.numpy as jnp
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.core import Allocator, ContextLayout


def test_sequential_offsets():
    a = Allocator(100)
    assert (a.alloc(30), a.alloc(30), a.alloc(30)) == (0, 30, 60)


def test_split_hole_first_fit():
    a = Allocator(100)
    o1, o2, o3 = a.alloc(30), a.alloc(30), a.alloc(30)
    a.free(o2)
    # First fit: a 20-word request reuses the start of the freed hole.
    assert a.alloc(20) == 30
    # The hole has 10 words left at offset 50; a 10-word fit lands there.
    assert a.alloc(10) == 50


def test_first_fit_and_reuse_exact():
    a = Allocator(100)
    o1, o2, o3 = a.alloc(30), a.alloc(30), a.alloc(30)
    a.free(o2)
    assert a.alloc(30) == 30          # exact reuse
    a.free(o1)
    a.free(o3)
    assert a.live_words == 30
    with pytest.raises(MemoryError):
        a.alloc(80)                    # fragmented: 30 live in the middle


def test_merge_on_free_defragments():
    a = Allocator(90)
    o1, o2, o3 = a.alloc(30), a.alloc(30), a.alloc(30)
    a.free(o1)
    a.free(o3)
    assert a.n_free_chunks == 2
    a.free(o2)                         # merges with both neighbours
    assert a.n_free_chunks == 1
    assert a.alloc(90) == 0


def test_exhaustion_raises():
    a = Allocator(10)
    a.alloc(10)
    with pytest.raises(MemoryError):
        a.alloc(1)


def test_double_free_raises():
    a = Allocator(10)
    o = a.alloc(5)
    a.free(o)
    with pytest.raises(ValueError):
        a.free(o)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(1, 20), min_size=1, max_size=30),
       st.integers(0, 2**31 - 1))
def test_allocator_invariants_property(sizes, seed):
    """Random alloc/free interleaving preserves: no overlap, live-word
    accounting, and full-merge on total free."""
    import random
    rng = random.Random(seed)
    a = Allocator(400)
    live = {}
    for s in sizes:
        try:
            off = a.alloc(s)
        except MemoryError:
            continue
        # No overlap with any live allocation.
        for o2, s2 in live.items():
            assert off + s <= o2 or o2 + s2 <= off
        live[off] = s
        if live and rng.random() < 0.4:
            victim = rng.choice(list(live))
            a.free(victim)
            del live[victim]
    assert a.live_words == sum(live.values())
    for off in list(live):
        a.free(off)
    assert a.live_words == 0
    assert a.n_free_chunks == 1


def test_layout_drop_frees_and_reuses():
    lo = ContextLayout(capacity_words=64)
    lo.add("a", (32,), jnp.float32)
    lo.add("b", (32,), jnp.int32)
    assert lo.live_words == 64
    lo.drop("a")
    assert lo.live_words == 32
    lo.add("c", (16,), jnp.float32)
    assert lo.offset("c") == 0         # reused the freed region
    assert lo.mu_bytes == 64 * 4       # μ is the fixed capacity


def test_layout_rejects_zero_size_fields():
    """Regression: a zero-dim shape used to report field_words() == 0 while
    the allocator reserved max(words, 1) == 1, so ledger byte counts and
    Allocator.live_words disagreed.  Zero-size fields are now an error."""
    lo = ContextLayout(capacity_words=16)
    with pytest.raises(ValueError):
        lo.add("empty", (0,), jnp.int32)
    with pytest.raises(ValueError):
        lo.add("empty2", (4, 0), jnp.float32)
    # The failed adds must not leak allocations or register the name.
    assert lo.live_words == 0
    lo.add("ok", (16,), jnp.int32)          # full capacity still available
    assert lo.live_words == 16
    # Scalar (shape ()) fields still occupy one word.
    lo2 = ContextLayout()
    lo2.add("scalar", (), jnp.int32)
    assert lo2.field_words("scalar") == 1


def test_layout_rejects_narrow_dtypes():
    lo = ContextLayout()
    with pytest.raises(TypeError):
        lo.add("h", (4,), jnp.bfloat16)
