"""Behavioural tests for the PEMS core: executor rounds, drivers, collectives
vs numpy oracles, and multi-real-processor (P>1) equivalence via subprocess."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.core import ContextLayout, Pems, PemsConfig


def make_layout(v, omega, n=16):
    return (
        ContextLayout()
        .add("data", (n,), jnp.int32)
        .add("acc", (1,), jnp.int32)
        .add("send", (v, omega), jnp.int32)
        .add("scnt", (v,), jnp.int32)
        .add("recv", (v, omega), jnp.int32)
        .add("rcnt", (v,), jnp.int32)
    )


def fill_send(rho, ctx, v, omega):
    msgs = (rho * 1000 + jnp.arange(v, dtype=jnp.int32))[:, None]
    msgs = msgs * jnp.ones((1, omega), jnp.int32) + jnp.arange(omega, dtype=jnp.int32)
    cnt = (rho + jnp.arange(v, dtype=jnp.int32)) % omega + 1
    return ctx.set("send", msgs).set("scnt", cnt)


# --------------------------------------------------------------------------- #
# Superstep engine                                                             #
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("driver", ["explicit", "sliced", "async"])
@pytest.mark.parametrize("v,k", [(4, 1), (8, 2), (8, 4), (12, 3)])
def test_superstep_rounds_all_drivers(v, k, driver):
    lo = make_layout(v, 4)
    pems = Pems(PemsConfig(v=v, k=k, driver=driver), lo)
    store = pems.init(lambda rho: {"data": rho * jnp.ones(16, jnp.int32)})

    def step(rho, ctx):
        return ctx.set("acc", ctx.get("data")[:1] * 2 + rho)

    store = pems.superstep(store, step, reads=["data"], writes=["acc"])
    acc = np.asarray(store.field("acc"))[:, 0]
    np.testing.assert_array_equal(acc, np.arange(v) * 3)


def test_sliced_driver_only_writes_declared_fields():
    v = 4
    lo = make_layout(v, 4)
    pems = Pems(PemsConfig(v=v, k=2, driver="sliced"), lo)
    store = pems.init(lambda rho: {"data": rho * jnp.ones(16, jnp.int32)})

    def step(rho, ctx):
        # Tries to clobber "data", but only "acc" is declared as written.
        return ctx.set("data", jnp.zeros(16, jnp.int32)).set(
            "acc", jnp.ones(1, jnp.int32)
        )

    store = pems.superstep(store, step, reads=["data"], writes=["acc"])
    np.testing.assert_array_equal(
        np.asarray(store.field("data"))[:, 0], np.arange(v)
    )
    np.testing.assert_array_equal(np.asarray(store.field("acc"))[:, 0], 1)


def test_superstep_jits_and_is_deterministic():
    v, k = 8, 2
    lo = make_layout(v, 4)
    pems = Pems(PemsConfig(v=v, k=k), lo)

    @jax.jit
    def prog(data):
        from repro.core import ContextStore
        store = ContextStore(lo, data)
        store = pems.superstep(store, lambda rho, c: c.set("acc", rho[None]))
        return store.data

    store = pems.init()
    out1, out2 = prog(store.data), prog(store.data)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


# --------------------------------------------------------------------------- #
# Alltoallv                                                                    #
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("mode", ["direct", "indirect"])
@pytest.mark.parametrize("v,k,omega", [(4, 1, 2), (8, 2, 4), (6, 3, 8)])
def test_alltoallv_transposes_messages(v, k, omega, mode):
    lo = make_layout(v, omega)
    pems = Pems(PemsConfig(v=v, k=k), lo)
    store = pems.init()
    store = pems.superstep(store, lambda r, c: fill_send(r, c, v, omega))
    store = pems.alltoallv(store, "send", "recv", "scnt", "rcnt", mode=mode)

    S = np.asarray(store.field("send"))
    R = np.asarray(store.field("recv"))
    C = np.asarray(store.field("scnt"))
    Rc = np.asarray(store.field("rcnt"))
    np.testing.assert_array_equal(R, np.swapaxes(S, 0, 1))
    np.testing.assert_array_equal(Rc, C.T)


def test_alltoallv_direct_equals_indirect():
    v, k, omega = 8, 2, 4
    lo = make_layout(v, omega)
    a = Pems(PemsConfig(v=v, k=k), lo)
    b = Pems(PemsConfig(v=v, k=k), lo)
    sa = a.superstep(a.init(), lambda r, c: fill_send(r, c, v, omega))
    sb = b.superstep(b.init(), lambda r, c: fill_send(r, c, v, omega))
    sa = a.alltoallv(sa, "send", "recv", mode="direct")
    sb = b.alltoallv(sb, "send", "recv", mode="indirect")
    np.testing.assert_array_equal(
        np.asarray(sa.field("recv")), np.asarray(sb.field("recv"))
    )
    # ...and PEMS2 moves strictly fewer bytes (Cor 7.1.4) once ω ≳ B is not
    # required because the boundary cache charge is included:
    assert a.ledger.io_total != b.ledger.io_total


@pytest.mark.parametrize("v,k,omega", [
    (4, 1, 2), (8, 2, 4), (6, 3, 129),
    (4, 1, 1024),   # ω past the row-loop cutover: vectorised delivery path
])
def test_alltoallv_fused_equals_dense(v, k, omega):
    """The word-level kernel path (use_kernel=True, the default) is
    bit-identical to the seed dense-transpose path, payload and counts,
    and charges the same ledger events."""
    outs, ledgers = [], []
    for use_kernel in (True, False):
        lo = make_layout(v, omega)
        pems = Pems(PemsConfig(v=v, k=k), lo)
        store = pems.init()
        store = pems.superstep(store, lambda r, c: fill_send(r, c, v, omega))
        store = pems.alltoallv(store, "send", "recv", "scnt", "rcnt",
                               use_kernel=use_kernel)
        outs.append((np.asarray(store.field("recv")),
                     np.asarray(store.field("rcnt"))))
        ledgers.append(pems.ledger.io_total)
    np.testing.assert_array_equal(outs[0][0], outs[1][0])
    np.testing.assert_array_equal(outs[0][1], outs[1][1])
    assert ledgers[0] == ledgers[1]


@pytest.mark.parametrize("mode", ["direct", "indirect"])
@pytest.mark.parametrize("use_kernel", [True, False])
@pytest.mark.parametrize("omega", [5, 1024])   # row-loop and vectorised paths
def test_alltoallv_fill_fuses_boundary_mask(mode, use_kernel, omega):
    """fill=x masks lanes past counts[s, d] during delivery (the fused
    boundary fix-up), identically on every implementation path."""
    v, k = 6, 2
    lo = make_layout(v, omega)
    pems = Pems(PemsConfig(v=v, k=k), lo)
    store = pems.init()
    store = pems.superstep(store, lambda r, c: fill_send(r, c, v, omega))
    store = pems.alltoallv(store, "send", "recv", "scnt", "rcnt",
                           mode=mode, fill=-42, use_kernel=use_kernel)
    S = np.asarray(store.field("send"))
    C = np.asarray(store.field("scnt"))
    R = np.asarray(store.field("recv"))
    lane = np.arange(omega)[None, None, :]
    want = np.where(lane < C.T[:, :, None], np.swapaxes(S, 0, 1), -42)
    np.testing.assert_array_equal(R, want)
    np.testing.assert_array_equal(np.asarray(store.field("rcnt")), C.T)


def test_alltoallv_send_recv_aliasing():
    """send == recv (in-place shuffle) must match the dense path — the
    row-loop delivery is skipped for aliased fields since it reads source
    rows after overwriting them."""
    v, k, omega = 6, 2, 4
    outs = []
    for use_kernel in (True, False):
        lo = make_layout(v, omega)
        pems = Pems(PemsConfig(v=v, k=k), lo)
        store = pems.init()
        store = pems.superstep(store, lambda r, c: fill_send(r, c, v, omega))
        S = np.asarray(store.field("send"))
        store = pems.alltoallv(store, "send", "send", use_kernel=use_kernel)
        outs.append(np.asarray(store.field("send")))
        np.testing.assert_array_equal(outs[-1], np.swapaxes(S, 0, 1))
    np.testing.assert_array_equal(outs[0], outs[1])


def test_alltoallv_fill_requires_counts():
    lo = make_layout(4, 2)
    pems = Pems(PemsConfig(v=4), lo)
    with pytest.raises(ValueError):
        pems.alltoallv(pems.init(), "send", "recv", fill=0)


def test_field_words_roundtrip():
    """ContextStore word-level API: field_words_view/with_field_words are
    exact inverses and bit-compatible with the typed accessors."""
    from repro.core import ContextStore
    v = 4
    lo = make_layout(v, 3)
    pems = Pems(PemsConfig(v=v), lo)
    store = pems.init(
        lambda rho: {"data": rho * jnp.ones(16, jnp.int32),
                     "send": jnp.full((v, 3), -rho, jnp.int32)}
    )
    W = store.field_words_view("send")
    assert W.shape == (v, v * 3) and W.dtype == jnp.uint32
    np.testing.assert_array_equal(
        np.asarray(W).view(np.int32).reshape(v, v, 3),
        np.asarray(store.field("send")),
    )
    store2 = store.with_field_words("recv", W)
    np.testing.assert_array_equal(
        np.asarray(store2.field("recv")), np.asarray(store.field("send"))
    )
    # Other fields untouched.
    np.testing.assert_array_equal(
        np.asarray(store2.field("data")), np.asarray(store.field("data"))
    )
    with pytest.raises(TypeError):
        store.with_field_words("recv", W.astype(jnp.int32))


# --------------------------------------------------------------------------- #
# Rooted collectives vs oracles                                                #
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("root", [0, 3, 7])
def test_bcast(root):
    v = 8
    lo = ContextLayout().add("x", (5,), jnp.float32)
    pems = Pems(PemsConfig(v=v, k=2), lo)
    store = pems.init(lambda rho: {"x": jnp.full(5, rho, jnp.float32)})
    store = pems.bcast(store, "x", root=root)
    X = np.asarray(store.field("x"))
    np.testing.assert_array_equal(X, np.full((v, 5), root, np.float32))


@pytest.mark.parametrize("root", [0, 2])
def test_gather(root):
    v = 4
    lo = (ContextLayout()
          .add("x", (3,), jnp.int32)
          .add("gath", (v, 3), jnp.int32))
    pems = Pems(PemsConfig(v=v, k=1), lo)
    store = pems.init(lambda rho: {"x": rho * 10 + jnp.arange(3, dtype=jnp.int32)})
    store = pems.gather(store, "x", "gath", root=root)
    G = np.asarray(store.field("gath"))
    want = np.arange(v)[:, None] * 10 + np.arange(3)
    np.testing.assert_array_equal(G[root], want)
    # Non-root contexts untouched (zeros).
    for r in range(v):
        if r != root:
            np.testing.assert_array_equal(G[r], 0)


def test_allgather():
    v = 4
    lo = (ContextLayout()
          .add("x", (2,), jnp.int32)
          .add("gath", (v, 2), jnp.int32))
    pems = Pems(PemsConfig(v=v, k=2), lo)
    store = pems.init(lambda rho: {"x": jnp.full(2, rho, jnp.int32)})
    store = pems.allgather(store, "x", "gath")
    G = np.asarray(store.field("gath"))
    want = np.broadcast_to(np.arange(v)[:, None] * np.ones(2, int), (v, 2))
    for r in range(v):
        np.testing.assert_array_equal(G[r], want)


@pytest.mark.parametrize("op,np_op", [("add", np.sum), ("max", np.max),
                                      ("min", np.min)])
def test_reduce_ops(op, np_op):
    v, n = 8, 6
    lo = (ContextLayout()
          .add("x", (n,), jnp.float32)
          .add("out", (n,), jnp.float32))
    pems = Pems(PemsConfig(v=v, k=2), lo)
    store = pems.init(
        lambda rho: {"x": (rho + 1.0) * jnp.arange(1, n + 1, dtype=jnp.float32)}
    )
    store = pems.reduce(store, "x", "out", op=op, root=3)
    X = np.asarray(store.field("x"))
    O = np.asarray(store.field("out"))
    np.testing.assert_allclose(O[3], np_op(X, axis=0), rtol=1e-6)


def test_allreduce():
    v, n = 4, 3
    lo = (ContextLayout()
          .add("x", (n,), jnp.float32)
          .add("out", (n,), jnp.float32))
    pems = Pems(PemsConfig(v=v, k=2), lo)
    store = pems.init(lambda rho: {"x": jnp.full(n, rho + 1.0, jnp.float32)})
    store = pems.allreduce(store, "x", "out", op="add")
    O = np.asarray(store.field("out"))
    np.testing.assert_allclose(O, np.full((v, n), 10.0))


# --------------------------------------------------------------------------- #
# Property tests                                                               #
# --------------------------------------------------------------------------- #

@settings(max_examples=20, deadline=None)
@given(
    v_over_k=st.integers(1, 4),
    k=st.integers(1, 3),
    omega=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_alltoallv_roundtrip_property(v_over_k, k, omega, seed):
    """alltoallv twice == identity on message payloads (transpose involution)."""
    v = v_over_k * k
    lo = make_layout(v, omega)
    pems = Pems(PemsConfig(v=v, k=k), lo)
    rng = np.random.default_rng(seed)
    M = rng.integers(0, 2**31 - 1, size=(v, v, omega), dtype=np.int32)
    store = pems.init().with_field("send", jnp.asarray(M))
    store = pems.alltoallv(store, "send", "recv")
    store = store.with_field("send", store.field("recv"))
    store = pems.alltoallv(store, "send", "recv")
    np.testing.assert_array_equal(np.asarray(store.field("recv")), M)


# --------------------------------------------------------------------------- #
# Multi-real-processor (P > 1): subprocess with fake devices                    #
# --------------------------------------------------------------------------- #

_P_GT_1 = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import Pems, PemsConfig, ContextLayout, analysis

    v, k, P, omega = 16, 2, 4, 4
    mesh = jax.make_mesh((P,), ("vp",))

    def make_lo():
        return (ContextLayout()
                .add("send", (v, omega), jnp.int32)
                .add("scnt", (v,), jnp.int32)
                .add("recv", (v, omega), jnp.int32)
                .add("rcnt", (v,), jnp.int32))

    def step(rho, ctx):
        msgs = (rho * 1000 + jnp.arange(v, dtype=jnp.int32))[:, None]
        msgs = msgs * jnp.ones((1, omega), jnp.int32)
        cnt = (rho + jnp.arange(v, dtype=jnp.int32)) % omega + 1
        return ctx.set("send", msgs).set("scnt", cnt)

    # Fused (src_proc, dst_proc)-tiled word-level route (use_kernel=True,
    # the default) vs the dense _global_transpose reference: bit-identical
    # payload, counts, and ledger for every network chunking.
    for alpha in (None, 1, 2):
        outs = []
        for use_kernel in (True, False):
            pems = Pems(PemsConfig(v=v, k=k, P=P, alpha=alpha), make_lo(),
                        mesh=mesh)
            store = pems.superstep(pems.init(), step)
            store = pems.alltoallv(store, "send", "recv", "scnt", "rcnt",
                                   fill=-7, use_kernel=use_kernel)
            outs.append((np.asarray(store.field("recv")),
                         np.asarray(store.field("rcnt")),
                         pems.ledger.io_total, pems.ledger.network_rounds))
        np.testing.assert_array_equal(outs[0][0], outs[1][0])
        np.testing.assert_array_equal(outs[0][1], outs[1][1])
        assert outs[0][2] == outs[1][2]
        S = np.asarray(store.field("send"))
        C = np.asarray(store.field("scnt"))
        lane = np.arange(omega)[None, None, :]
        want = np.where(lane < C.T[:, :, None], np.swapaxes(S, 0, 1), -7)
        np.testing.assert_array_equal(outs[0][0], want)
        np.testing.assert_array_equal(outs[0][1], C.T)
        assert outs[0][3] == analysis.pems2_alltoallv_par_network_rounds(
            v, P, k, alpha)

    # Plain transpose (no counts) through the fused mesh route + bcast.
    pems = Pems(PemsConfig(v=v, k=k, P=P), make_lo(), mesh=mesh)
    store = pems.superstep(pems.init(), step)
    store = pems.alltoallv(store, "send", "recv")
    S = np.asarray(store.field("send"))
    R = np.asarray(store.field("recv"))
    np.testing.assert_array_equal(R, np.swapaxes(S, 0, 1))

    store = pems.bcast(store, "recv", root=5)
    R2 = np.asarray(store.field("recv"))
    np.testing.assert_array_equal(R2, np.broadcast_to(R[5], R2.shape))
    print("MULTIPROC_OK")
""")


def test_multiprocessor_alltoallv_subprocess():
    r = subprocess.run(
        [sys.executable, "-c", _P_GT_1],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
             # Without an explicit platform, jax probes for TPUs via the
             # cloud metadata URL and stalls for minutes off-cloud.
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")},
        cwd="/root/repo",
    )
    assert "MULTIPROC_OK" in r.stdout, r.stderr[-3000:]
