"""Dry-run machinery tests: the lower+compile path on the production meshes
(subprocess: needs 512 fake devices before jax init), HLO collective parsing,
and roofline-term math."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.roofline import collective_bytes, roofline_terms


def test_collective_bytes_parser():
    hlo = textwrap.dedent("""
      %all-gather.1 = bf16[16,1024]{1,0} all-gather(%p0), replica_groups={}
      %all-reduce.2 = f32[256]{0} all-reduce(%x), to_apply=%add
      %ar.3 = (f32[8,8]{1,0}, f32[16]{0}) all-reduce(%a, %b), to_apply=%add
      %rs = f32[64]{0} reduce-scatter(%y), dimensions={0}
      %a2a = bf16[4,32]{1,0} all-to-all(%z), dimensions={0}
      %cp = u32[128]{0} collective-permute(%w), source_target_pairs={{0,1}}
      %not-a-collective = f32[999]{0} add(%q, %r)
    """)
    got = collective_bytes(hlo)
    b = got["bytes_by_kind"]
    assert b["all-gather"] == 16 * 1024 * 2
    assert b["all-reduce"] == 256 * 4 + (64 * 4 + 16 * 4)
    assert b["reduce-scatter"] == 64 * 4
    assert b["all-to-all"] == 4 * 32 * 2
    assert b["collective-permute"] == 128 * 4
    assert got["count_by_kind"]["all-reduce"] == 2
    # weighted: all-reduce counts double
    want = (b["all-gather"] + 2 * b["all-reduce"] + b["reduce-scatter"]
            + b["all-to-all"] + b["collective-permute"])
    assert got["weighted_bytes"] == want


def test_roofline_terms_math():
    t = roofline_terms(flops=197e12, bytes_accessed=819e9, coll_bytes=0.0)
    assert abs(t["compute_s"] - 1.0) < 1e-9
    assert abs(t["memory_s"] - 1.0) < 1e-9
    assert t["collective_s"] == 0.0
    t2 = roofline_terms(flops=1e12, bytes_accessed=819e9, coll_bytes=500e9)
    assert t2["dominant"] == "collective"
    assert t2["roofline_fraction"] < 0.01


_DRYRUN = textwrap.dedent("""
    import json, sys
    from repro.launch.dryrun import run_cell
    for multi in (False, True):
        res = run_cell("mamba2-130m", "train_4k", multi)
        assert res["memory"]["per_device_bytes"] > 0
        assert res["cost"]["flops_per_device"] > 0
        assert res["roofline"]["dominant"] in ("compute", "memory",
                                               "collective")
        print("MESH_OK", "multi" if multi else "single",
              res["mesh"], res["roofline"]["dominant"])
""")


@pytest.mark.slow
def test_dryrun_compiles_on_both_production_meshes():
    """Full 512-device lower+compile for one arch on 16x16 and 2x16x16."""
    r = subprocess.run(
        [sys.executable, "-c", _DRYRUN],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": os.environ.get("PATH", ""),
             # Without an explicit platform, jax probes for TPUs via the
             # cloud metadata URL and stalls for minutes off-cloud.
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu"),
             },
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert r.stdout.count("MESH_OK") == 2, (r.stdout, r.stderr[-3000:])
    assert "'pod': 2" in r.stdout


def test_dryrun_artifacts_if_present():
    """When the full sweep has been run, every non-skipped cell must have
    compiled successfully on both meshes."""
    art = "artifacts/dryrun"
    if not os.path.isdir(art) or not os.listdir(art):
        pytest.skip("dry-run artifacts not generated in this environment")
    bad = []
    seen = 0
    for fn in os.listdir(art):
        with open(os.path.join(art, fn)) as f:
            d = json.load(f)
        if "error" in d:
            bad.append(fn)
        elif "skipped" not in d:
            seen += 1
    assert not bad, bad
    assert seen >= 10
