"""Fig 8.24 analogue: Euler tour of random forests, scaling the node count."""

from __future__ import annotations

import numpy as np

from repro.pems_apps import euler_tour
from .common import emit, time_fn


def _forest(rng, n, trees):
    parent = np.arange(n)
    for i in range(trees, n):
        parent[i] = rng.integers(0, i)
    return parent


def run():
    rng = np.random.default_rng(4)
    for n in (256, 1024, 4096):
        parent = _forest(rng, n, 4)
        us = time_fn(lambda p=parent: euler_tour(p, v=8, k=2), iters=1)
        emit(f"euler_tour_n{n}", us, "trees=4")
