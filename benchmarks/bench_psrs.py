"""Figs 8.2–8.6 analogue: PSRS on PEMS2 (direct) vs PEMS1 (indirect) vs the
hand-built EM sort stand-in (jnp.sort ≙ STXXL), scaling the problem via v;
plus the P-scaling I/O model (wall-clock P>1 needs real hosts).

Three instrumented sections land in ``BENCH_psrs.json``
(``BENCH_psrs.smoke.json`` under ``BENCH_FAST=1``/``--smoke``):

* ``phases`` — per-stage wall clock of one ``psrs_plan`` run on the memmap
  tier (whose executor jits each stage body — the device tier only jits
  the fused whole program), grouped into the thesis' three buckets: ``local_sort_s`` (sort_sample),
  ``network_s`` (sampling collectives + partition + alltoallv) and
  ``merge_s``; ``merge_dense_s`` is the same merge stage re-timed with
  ``merge_kernel=False`` for the end-to-end view of what the kernel buys.
* ``merge`` — the *paired-sample* kernel-vs-dense statistic the regression
  gate holds: on authentic post-delivery buckets (the real ``brecv`` /
  ``brcnt`` extracted after running the plan through alltoallv), the tiled
  k-way merge and the seed's dense ``jnp.sort(flat)[:rcap]`` re-sort run
  interleaved in the same process; ``speedup_vs_dense`` is the median of
  per-iteration (dense / kernel) wall-time ratios, so machine speed
  cancels and the ratio transfers across runner generations.  A silent
  fallback to the dense path would read as speedup ≈ 1.0 and fail the
  gate's floor.
* ``stream`` — PSRS on a disk backing: the merge superstep runs with
  ``stream=True``, so ``merge_prefetch_events`` must be nonzero (bucket
  reads submitted ahead of need, overlapping merge compute) — the gate
  fails a run whose streamed merge stopped overlapping.
"""

from __future__ import annotations

import json
import os
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import analysis
from repro.kernels.kway_merge import kway_merge
from repro.pems_apps import psrs_plan, psrs_sort
from repro.pems_apps.common import INT_MAX
from .common import TRACER, emit, time_fn

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Which context field each stage writes last — blocked on for honest
# per-stage wall clock under JAX's async dispatch.
_STAGE_SYNC = {
    "sort_sample": "samp",
    "gather_samples": "allsamp",
    "pick_splitters": "gsplit",
    "bcast_splitters": "gsplit",
    "partition": "bsend",
    "alltoallv": "brecv",
    "merge": "result",
}

_NETWORK_STAGES = ("gather_samples", "pick_splitters", "bcast_splitters",
                   "partition", "alltoallv")


def _run_steps(load, steps, data, until=None):
    store = load(data)
    for name, step in steps:
        store = step(store)
        if name == until:
            break
    return store


def _phase_rows(td: str, n: int, v: int, k: int, rng) -> dict:
    """One plan run, each stage timed (min of 2 after a warmup pass).

    Runs on ``tier="memmap"``: the tiered executor jits each stage *body*
    and completes its I/O before returning, so per-stage wall clock is
    honest — the device tier only jits the whole fused program, and
    stepping it stage by stage would time eager re-traces instead."""
    n_v = n // v
    data = jnp.asarray(rng.integers(-2**31, 2**31 - 1, size=(v, n_v),
                                    dtype=np.int32))
    path = os.path.join(td, "phases.bin")
    _, load, steps, extract = psrs_plan(v, n_v, k, tier="memmap",
                                        backing_path=path)
    _run_steps(load, steps, data)                      # warmup: trace + jit
    stage_s = {name: float("inf") for name, _ in steps}
    for _ in range(2):
        store = load(data)
        for name, step in steps:
            with TRACER.span(f"stage:{name}", tid="bench",
                             cat="stage") as sp:
                store = step(store)
                jax.block_until_ready(store.field(_STAGE_SYNC[name]))
            stage_s[name] = min(stage_s[name], sp.duration_s)
    result, _, oflow = extract(store)
    assert not np.asarray(oflow).any()
    assert (np.asarray(result).reshape(-1) < np.inf).all()

    # The same merge stage with the dense re-sort, for the e2e comparison
    # (the gated statistic is the paired op-level ratio in ``merge``).
    _, dload, dsteps, _ = psrs_plan(v, n_v, k, tier="memmap",
                                    backing_path=path + ".dense",
                                    merge_kernel=False)
    _run_steps(dload, dsteps, data)
    dense_s = float("inf")
    for _ in range(2):
        store = _run_steps(dload, dsteps, data, until="alltoallv")
        with TRACER.span("stage:merge_dense", tid="bench",
                         cat="stage") as sp:
            store = dict(dsteps)["merge"](store)
            jax.block_until_ready(store.field("result"))
        dense_s = min(dense_s, sp.duration_s)

    return {
        "n_words": n, "v": v, "k": k,
        "stages": {name: round(s, 5) for name, s in stage_s.items()},
        "local_sort_s": round(stage_s["sort_sample"], 5),
        "network_s": round(sum(stage_s[s] for s in _NETWORK_STAGES), 5),
        "merge_s": round(stage_s["merge"], 5),
        "merge_dense_s": round(dense_s, 5),
    }


def _merge_pair_row(n: int, v: int, k: int, tile: int, rng,
                    iters: int) -> dict:
    """Paired kernel-vs-dense merge on authentic post-delivery buckets."""
    n_v = n // v
    data = jnp.asarray(rng.integers(-2**31, 2**31 - 1, size=(v, n_v),
                                    dtype=np.int32))
    _, load, steps, _ = psrs_plan(v, n_v, k)
    store = _run_steps(load, steps, data, until="alltoallv")
    brecv = jax.block_until_ready(store.field("brecv"))    # [v, v, cap]
    brcnt = jax.block_until_ready(store.field("brcnt"))    # [v, v]
    cap, rcap = brecv.shape[-1], 2 * n_v

    f_kernel = jax.jit(jax.vmap(
        lambda b, c: kway_merge(b, c, rcap=rcap, tile=tile,
                                fill=INT_MAX)[0]))
    f_dense = jax.jit(jax.vmap(lambda b: jnp.sort(b.reshape(-1))[:rcap]))
    out_k = jax.block_until_ready(f_kernel(brecv, brcnt))
    out_d = jax.block_until_ready(f_dense(brecv))
    assert (np.asarray(out_k) == np.asarray(out_d)).all(), \
        "kernel merge diverged from the dense re-sort"

    ratios, d_best, k_best = [], float("inf"), float("inf")
    for _ in range(iters):                 # interleaved: machine speed cancels
        with TRACER.span("merge_dense", tid="bench") as sp:
            jax.block_until_ready(f_dense(brecv))
        d_s = sp.duration_s
        with TRACER.span("merge_kernel", tid="bench") as sp:
            jax.block_until_ready(f_kernel(brecv, brcnt))
        k_s = sp.duration_s
        ratios.append(d_s / k_s)
        d_best, k_best = min(d_best, d_s), min(k_best, k_s)
    ratios.sort()
    return {
        "n_words": n, "v": v, "omega": cap, "rcap": rcap, "tile": tile,
        "dense_ms": round(d_best * 1e3, 3),
        "kernel_ms": round(k_best * 1e3, 3),
        "speedup_vs_dense": round(ratios[len(ratios) // 2], 3),
    }


def _stream_row(td: str, n: int, v: int, k: int, tier: str, driver: str,
                rng) -> dict:
    keys = rng.integers(-2**31, 2**31 - 1, size=n, dtype=np.int32)
    with TRACER.span(f"stream_{tier}_{driver}", tid="bench") as sp:
        out, pems = psrs_sort(
            keys, v=v, k=k, driver=driver, tier=tier,
            backing_path=os.path.join(td, f"stream_{tier}_{driver}.bin"),
            return_pems=True)
    wall_s = sp.duration_s
    assert (out == np.sort(keys)).all(), f"streamed sort diverged: {tier}"
    ts = pems.tier_stats
    return {
        "tier": tier, "driver": driver, "n": n, "v": v, "k": k,
        "wall_s": round(wall_s, 3),
        "merge_prefetch_events": ts.merge_prefetch_events,
        "merge_stall_s": round(ts.merge_stall_s, 4),
        "overlap_fraction": round(ts.overlap_fraction, 4),
    }


def _obs_row(td: str, n: int, v: int, k: int, rng, iters: int) -> dict:
    """Paired traced-vs-untraced PSRS: the tracing-overhead statistic.

    Interleaved in-process like the merge pair, so machine speed cancels:
    ``overhead_ratio`` is the median per-iteration (traced / untraced)
    wall-time ratio on the async file-tier sort — the configuration with
    the most instrumentation (engine request spans, round spans, stage
    spans).  The regression gate caps it (``--obs-overhead``).  One traced
    run's merged Perfetto trace is exported to ``BENCH_psrs.trace.json``
    as the CI artifact."""
    keys = rng.integers(-2**31, 2**31 - 1, size=n, dtype=np.int32)

    def run_once(trace: bool, tag: str) -> float:
        with TRACER.span(f"obs_{tag}", tid="bench") as sp:
            psrs_sort(keys, v=v, k=k, driver="async", tier="file",
                      backing_path=os.path.join(td, f"obs_{tag}.bin"),
                      trace=trace)
        return sp.duration_s

    run_once(False, "warm_plain")
    run_once(True, "warm_traced")
    ratios, plain_best, traced_best = [], float("inf"), float("inf")
    for _ in range(iters):
        p_s = run_once(False, "plain")
        t_s = run_once(True, "traced")
        ratios.append(t_s / p_s)
        plain_best, traced_best = min(plain_best, p_s), min(traced_best, t_s)
    ratios.sort()

    _, pems = psrs_sort(keys, v=v, k=k, driver="async", tier="file",
                        backing_path=os.path.join(td, "obs_artifact.bin"),
                        trace=True, return_pems=True)
    pems.export_trace(os.path.join(REPO_ROOT, "BENCH_psrs.trace.json"))
    return {
        "tier": "file", "driver": "async", "n": n, "v": v, "k": k,
        "plain_s": round(plain_best, 4),
        "traced_s": round(traced_best, 4),
        "overhead_ratio": round(ratios[len(ratios) // 2], 3),
    }


def _figures(smoke: bool, rng) -> None:
    """The original Fig 8.2–8.6 CSV rows (unchanged semantics)."""
    sizes = (1 << 16,) if smoke else (1 << 16, 1 << 18, 1 << 20)
    for n in sizes:
        x = rng.integers(-2**31, 2**31 - 1, size=n, dtype=np.int32)
        v, k = 16, 4

        for mode in ("direct", "indirect"):
            out, pems = psrs_sort(x, v=v, k=k, mode=mode, return_pems=True)
            assert (out == np.sort(x)).all()
            us = time_fn(lambda: psrs_sort(x, v=v, k=k, mode=mode), iters=1)
            led = pems.ledger
            emit(f"psrs_{mode}_n{n}", us,
                 f"io={led.io_total};swap={led.swap_total};"
                 f"msg_ind={led.msg_indirect};disk={led.disk_space}")

        us = time_fn(lambda: np.asarray(jnp.sort(jnp.asarray(x))), iters=2)
        emit(f"stxxl_stand_in_jnp_sort_n{n}", us, "baseline")

    # Fig 8.6: relative speedup model as real processors are added (I/O-model
    # derived: the wall-clock needs real hosts; the ledger is exact).
    n = 1 << 20
    v, k, omega_b = 32, 4, (2 * (n // 32) // 32) * 4
    mu = (n // v) * 4 * 4
    base = None
    for P in (1, 2, 4, 8):
        io = analysis.pems2_alltoallv_par_io_exact(v, P, k, mu, omega_b, 4096)
        t = io / P     # per-processor I/O time (fully parallel disks)
        base = base or t
        emit(f"psrs_model_speedup_P{P}", t, f"speedup={base / t:.2f}")


def run(smoke: bool | None = None) -> None:
    if smoke is None:
        smoke = os.environ.get("BENCH_FAST") == "1"
    rng = np.random.default_rng(0)
    v, k = 16, 4

    _figures(smoke, rng)

    if smoke:
        phase_n = 1 << 17
        pair_cfgs = ((1 << 17, 256),)
        stream_n, iters = 1 << 15, 3
    else:
        phase_n = 1 << 20
        pair_cfgs = ((1 << 17, 256), (1 << 19, 256), (1 << 19, 1024))
        stream_n, iters = 1 << 17, 5

    with tempfile.TemporaryDirectory() as td:
        phases = [_phase_rows(td, phase_n, v, k, rng)]
    p = phases[0]
    emit(f"psrs_phases_n{phase_n}", p["merge_s"] * 1e6,
         f"local_sort={p['local_sort_s']};network={p['network_s']};"
         f"merge={p['merge_s']};merge_dense={p['merge_dense_s']}")

    merge_rows = []
    for n, tile in pair_cfgs:
        row = _merge_pair_row(n, v, k, tile, rng, iters)
        merge_rows.append(row)
        emit(f"psrs_merge_pair_n{n}_t{tile}", row["kernel_ms"] * 1e3,
             f"dense_ms={row['dense_ms']};"
             f"speedup={row['speedup_vs_dense']}")

    stream_rows = []
    with tempfile.TemporaryDirectory() as td:
        for tier, driver in (("file", "explicit"), ("file", "async"),
                             ("memmap", "explicit")):
            row = _stream_row(td, stream_n, 8, 2, tier, driver, rng)
            stream_rows.append(row)
            emit(f"psrs_stream_{tier}_{driver}", row["wall_s"] * 1e6,
                 f"prefetch={row['merge_prefetch_events']};"
                 f"stall={row['merge_stall_s']}")

    with tempfile.TemporaryDirectory() as td:
        obs_row = _obs_row(td, stream_n, 8, 2, rng, iters)
    emit("psrs_obs_overhead", obs_row["traced_s"] * 1e6,
         f"plain_s={obs_row['plain_s']};"
         f"ratio={obs_row['overhead_ratio']}")

    out = {
        "benchmark": "psrs_phases",
        "backend": jax.default_backend(),
        "smoke": bool(smoke),
        "v": v,
        "note": ("phases: per-stage wall clock of one psrs_plan run "
                 "(min of 2 after warmup), grouped local_sort / network / "
                 "merge; merge_dense_s re-times the merge stage with "
                 "merge_kernel=False.  merge: paired kernel-vs-dense rows "
                 "on authentic post-alltoallv buckets — speedup_vs_dense "
                 "is the median per-iteration (dense / kernel) ratio, "
                 "interleaved in-process so machine speed cancels; the "
                 "regression gate floors it, so a silent fallback to the "
                 "dense path cannot read green.  stream: PSRS on a disk "
                 "backing; merge_prefetch_events counts bucket reads "
                 "submitted ahead of need while the previous round merged "
                 "(must stay nonzero).  obs: paired traced-vs-untraced "
                 "sort — overhead_ratio is the median per-iteration "
                 "(traced / untraced) ratio, gated by --obs-overhead; "
                 "the traced run's merged Perfetto trace is exported to "
                 "BENCH_psrs.trace.json."),
        "phases": phases,
        "merge": merge_rows,
        "stream": stream_rows,
        "obs": obs_row,
    }
    name = "BENCH_psrs.smoke.json" if smoke else "BENCH_psrs.json"
    with open(os.path.join(REPO_ROOT, name), "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")

    best = max(r["speedup_vs_dense"] for r in merge_rows)
    emit("psrs_merge_best_speedup", 0.0, f"speedup_vs_dense={best}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(smoke="--smoke" in sys.argv or None)
