"""Figs 8.2–8.6 analogue: PSRS on PEMS2 (direct) vs PEMS1 (indirect) vs the
hand-built EM sort stand-in (jnp.sort ≙ STXXL), scaling the problem via v;
plus the P-scaling I/O model (wall-clock P>1 needs real hosts)."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import analysis
from repro.pems_apps import psrs_sort
from .common import emit, time_fn


def run():
    rng = np.random.default_rng(0)
    for n in (1 << 16, 1 << 18, 1 << 20):
        x = rng.integers(-2**31, 2**31 - 1, size=n, dtype=np.int32)
        v, k = 16, 4

        for mode in ("direct", "indirect"):
            out, pems = psrs_sort(x, v=v, k=k, mode=mode, return_pems=True)
            assert (out == np.sort(x)).all()
            us = time_fn(lambda: psrs_sort(x, v=v, k=k, mode=mode), iters=1)
            led = pems.ledger
            emit(f"psrs_{mode}_n{n}", us,
                 f"io={led.io_total};swap={led.swap_total};"
                 f"msg_ind={led.msg_indirect};disk={led.disk_space}")

        us = time_fn(lambda: np.asarray(jnp.sort(jnp.asarray(x))), iters=2)
        emit(f"stxxl_stand_in_jnp_sort_n{n}", us, "baseline")

    # Fig 8.6: relative speedup model as real processors are added (I/O-model
    # derived: the wall-clock needs real hosts; the ledger is exact).
    n = 1 << 20
    v, k, omega_b = 32, 4, (2 * (n // 32) // 32) * 4
    mu = (n // v) * 4 * 4
    base = None
    for P in (1, 2, 4, 8):
        io = analysis.pems2_alltoallv_par_io_exact(v, P, k, mu, omega_b, 4096)
        t = io / P     # per-processor I/O time (fully parallel disks)
        base = base or t
        emit(f"psrs_model_speedup_P{P}", t, f"speedup={base / t:.2f}")
