"""Figs 8.12–8.14 analogue: the same program under the three I/O drivers —
now in two flavours.

Device tier (the seed benchmark): prefix sum only touches its big field in
the first/last superstep, so the sliced ("mmap") driver's ledger collapses —
the thesis' flat mmap curves.

Backing tiers (the real thing): PSRS over a host/memmap store, where each
round's contexts genuinely move host↔device (and disk, for memmap).  The
``async`` driver's prefetch thread overlaps round ``r+1``'s swap-in with
round ``r``'s compute (PEMS2 §5.1); the measured overlap fraction and the
per-tier ledger bytes land in ``BENCH_drivers.json`` at the repo root.
"""

from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.pems_apps import prefix_sum, psrs_sort
from .common import emit, time_fn

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run():
    smoke = os.environ.get("BENCH_FAST") == "1"
    rng = np.random.default_rng(2)

    # ---- device tier: the seed driver comparison (ledger collapses) ------ #
    n = 1 << 18 if smoke else 1 << 20
    x = rng.integers(-100, 100, size=n, dtype=np.int32)
    for driver in ("explicit", "async", "sliced"):
        out, pems = prefix_sum(x, v=16, k=4, driver=driver, return_pems=True)
        assert (out == np.cumsum(x).astype(np.int32)).all()
        us = time_fn(lambda d=driver: prefix_sum(x, v=16, k=4, driver=d),
                     iters=1)
        led = pems.ledger
        emit(f"prefix_sum_{driver}_n{n}", us,
             f"swap={led.swap_total};io={led.io_total};"
             f"barriers={led.supersteps}")

    # ---- backing tiers: PSRS with real swaps ----------------------------- #
    n = 1 << 18 if smoke else 1 << 20
    v, k = 16, 2                      # 8 rounds/superstep: room to overlap
    keys = rng.integers(-2**31, 2**31 - 1, size=n, dtype=np.int32)
    want = np.sort(keys)
    rows = []
    for tier in ("host", "memmap"):
        for driver in ("explicit", "sliced", "async"):
            t0 = time.perf_counter()
            out, pems = psrs_sort(keys, v=v, k=k, driver=driver, tier=tier,
                                  return_pems=True)
            wall_s = time.perf_counter() - t0
            assert (out == want).all()
            led, ts = pems.ledger, pems.tier_stats
            row = {
                "tier": tier,
                "driver": driver,
                "n": n,
                "v": v,
                "k": k,
                "wall_s": round(wall_s, 3),
                "h2d_bytes": led.h2d_bytes,
                "d2h_bytes": led.d2h_bytes,
                "disk_read_bytes": led.disk_read_bytes,
                "disk_write_bytes": led.disk_write_bytes,
                "modeled_swap_bytes": led.swap_total,
                "modeled_io_bytes": led.io_total,
                "rounds": ts.rounds,
                "swap_in_s": round(ts.swap_in_s, 4),
                "swap_out_s": round(ts.swap_out_s, 4),
                "compute_s": round(ts.compute_s, 4),
                "stall_s": round(ts.stall_s, 4),
                "overlap_fraction": round(ts.overlap_fraction, 4),
            }
            rows.append(row)
            emit(f"psrs_{tier}_{driver}_n{n}", wall_s * 1e6,
                 f"h2d={led.h2d_bytes};disk_w={led.disk_write_bytes};"
                 f"overlap={row['overlap_fraction']}")

    out = {
        "benchmark": "drivers_backing_tier",
        "backend": jax.default_backend(),
        "smoke": smoke,
        "note": ("overlap_fraction = 1 - stall_s/swap_in_s: the share of "
                 "swap-in time the async prefetch thread hid behind round "
                 "compute (PEMS2 §5.1).  Synchronous drivers stall for every "
                 "swap-in, so their fraction is ~0 by construction."),
        "tiers": rows,
    }
    # Smoke runs write to a separate file so CI / BENCH_FAST sweeps never
    # clobber the full-sweep deliverable at the repo root.
    name = "BENCH_drivers.smoke.json" if smoke else "BENCH_drivers.json"
    with open(os.path.join(REPO_ROOT, name), "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")

    async_rows = [r for r in rows if r["driver"] == "async"]
    best = max(r["overlap_fraction"] for r in async_rows)
    emit("psrs_async_best_overlap", 0.0, f"overlap_fraction={best}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
