"""Figs 8.12–8.14 analogue: the same program under the three I/O drivers.
Prefix sum only touches its big field in the first/last superstep, so the
sliced ("mmap") driver's ledger collapses — the thesis' flat mmap curves."""

from __future__ import annotations

import numpy as np

from repro.pems_apps import prefix_sum
from .common import emit, time_fn


def run():
    rng = np.random.default_rng(2)
    n = 1 << 20
    x = rng.integers(-100, 100, size=n, dtype=np.int32)
    for driver in ("explicit", "async", "sliced"):
        out, pems = prefix_sum(x, v=16, k=4, driver=driver, return_pems=True)
        assert (out == np.cumsum(x).astype(np.int32)).all()
        us = time_fn(lambda d=driver: prefix_sum(x, v=16, k=4, driver=d),
                     iters=1)
        led = pems.ledger
        emit(f"prefix_sum_{driver}_n{n}", us,
             f"swap={led.swap_total};io={led.io_total};"
             f"barriers={led.supersteps}")
