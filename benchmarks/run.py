"""Benchmark driver — one module per thesis table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Set ``BENCH_FAST=1`` for the
reduced sweep (CI), ``DRYRUN_ARTIFACTS`` to point the roofline table at a
different artifact directory.

Figure map (see DESIGN.md §7):
  bench_alltoallv    Fig 7.2     bench_disk_space  Fig 6.2
  bench_collectives  Fig 7.7/7.8 bench_psrs        Fig 8.2–8.6
  bench_psrs_mu      Fig 8.7     bench_drivers     Fig 8.12–8.14
  bench_cgm          Fig 8.15–8.20  bench_euler    Fig 8.24
  bench_roofline     §Roofline (assignment)
  bench_io           §5.1 (async engine: driver × queue depth × block size)
"""

from __future__ import annotations

import sys
import traceback

from . import (
    bench_alltoallv,
    bench_cgm,
    bench_collectives,
    bench_disk_space,
    bench_drivers,
    bench_euler,
    bench_io,
    bench_psrs,
    bench_psrs_mu,
    bench_roofline,
)

MODULES = [
    ("disk_space", bench_disk_space),
    ("collectives", bench_collectives),
    ("alltoallv", bench_alltoallv),
    ("psrs", bench_psrs),
    ("psrs_mu", bench_psrs_mu),
    ("drivers", bench_drivers),
    ("io", bench_io),
    ("cgm", bench_cgm),
    ("euler", bench_euler),
    ("roofline", bench_roofline),
]


def main() -> None:
    print("name,us_per_call,derived")
    failed = []
    for name, mod in MODULES:
        try:
            mod.run()
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
