"""Figs 7.7/7.8: buffer space and run-time summary for every collective —
measured wall time, ledger I/O, and the closed-form time models."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ContextLayout, ContextStore, Pems, PemsConfig, analysis
from .common import emit, time_fn


def run():
    v, k, n = 16, 4, 256
    model = analysis.MachineModel()
    lo = (ContextLayout()
          .add("x", (n,), jnp.float32)
          .add("out", (n,), jnp.float32)
          .add("gath", (v, n), jnp.float32)
          .add("send", (v, n), jnp.int32)
          .add("recv", (v, n), jnp.int32))
    omega_b = n * 4
    mu = lo.live_bytes

    ops = {
        "bcast": (lambda p, st: p.bcast(st, "x"),
                  analysis.em_bcast_time(v, 1, k, mu, omega_b, model),
                  omega_b),
        "gather": (lambda p, st: p.gather(st, "x", "gath"),
                   analysis.em_gather_time(v, 1, mu, omega_b, model),
                   v * omega_b),
        "reduce": (lambda p, st: p.reduce(st, "x", "out"),
                   analysis.em_reduce_time(v, 1, k, n, 4, model),
                   k * n * 4),
        "alltoallv": (lambda p, st: p.alltoallv(st, "send", "recv"),
                      analysis.pems2_alltoallv_seq_time(
                          v, k, mu, omega_b, model),
                      analysis.pems2_alltoallv_seq_buffer(v, 1, 4096)),
    }
    for name, (fn, t_model, buf) in ops.items():
        pems = Pems(PemsConfig(v=v, k=k), lo)
        store = pems.init()

        @jax.jit
        def call(data, fn=fn, pems=pems):
            return fn(pems, ContextStore(lo, data)).data

        us = time_fn(call, store.data)
        pems2 = Pems(PemsConfig(v=v, k=k), lo)
        fn(pems2, pems2.init())
        emit(f"collective_{name}", us,
             f"io={pems2.ledger.io_total};buffer_bytes={buf};"
             f"model_time_blocks={t_model:.1f}")
