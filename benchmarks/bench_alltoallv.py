"""Fig 7.2 analogue: single EM-Alltoallv call, PEMS1-indirect vs PEMS2-direct,
k ∈ {1, 4}: wall time + ledger I/O + the thesis' analytic times.

Direct mode is additionally measured both ways through the collective layer:

* ``direct`` (the default path) — fused word-level delivery: slice the send
  word range, deliver (transpose + fused counts/boundary handling), rebuild
  the store row with a concatenate the delivery fuses into.
* ``direct_dense`` — the seed implementation (``use_kernel=False``): dense
  field gather → transpose → whole-store dynamic-update-slice.

Both are timed with the identical protocol (fresh output buffer per call,
as the seed benchmark did), interleaved iteration-by-iteration so machine
noise hits both equally; the comparison is written to
``BENCH_alltoallv.json`` at the repo root.
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from repro.core import ContextLayout, ContextStore, Pems, PemsConfig, analysis
from .common import emit

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
V = 16


def _interleaved_times(fused_fn, dense_fn, data, iters):
    """Time both paths back-to-back per iteration (identical protocol);
    returns paired (unsorted) seconds lists — consecutive samples share the
    machine state, so per-pair ratios cancel load drift."""
    jax.block_until_ready(fused_fn(data))                    # compile + warm
    jax.block_until_ready(dense_fn(data))
    tf, td = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fused_fn(data))
        tf.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(dense_fn(data))
        td.append(time.perf_counter() - t0)
    return tf, td


def run(smoke: bool | None = None) -> None:
    if smoke is None:
        smoke = os.environ.get("BENCH_FAST") == "1"
    sizes = (1 << 14, 1 << 16) if smoke else (1 << 14, 1 << 16, 1 << 18, 1 << 20)

    model = analysis.MachineModel(B=4096, S=1.0, G=1.0)
    configs = []
    for n_words in sizes:
        omega = n_words // (V * V)
        # Cheap configs get more samples: this box is noisy, and the robust
        # estimators below (paired-ratio and pooled medians) sharpen with
        # sample count.  Several rounds with fresh buffers/executables guard
        # against one unlucky allocation alignment dominating a process.
        iters = 6 if smoke else (100 if n_words <= 1 << 16 else 40)
        rounds = 1 if smoke else 3
        lo = (ContextLayout()
              .add("send", (V, omega), jnp.int32)
              .add("recv", (V, omega), jnp.int32))

        pems = Pems(PemsConfig(v=V, k=1), lo)
        store = pems.init()

        tf, td = [], []                        # all rounds' samples, pooled
        for _ in range(rounds):
            @jax.jit
            def fused_call(data):
                st = ContextStore(lo, data)
                st = pems.alltoallv(st, "send", "recv", mode="direct")
                return st.data

            @jax.jit
            def dense_call(data):
                st = ContextStore(lo, data)
                st = pems.alltoallv(st, "send", "recv", mode="direct",
                                    use_kernel=False)
                return st.data

            data = jnp.array(store.data)         # fresh buffer per round
            f, d = _interleaved_times(fused_call, dense_call, data, iters)
            tf.extend(f)
            td.extend(d)
        # Paired per-iteration ratios: the robust A/B statistic on a noisy
        # box (each pair ran back-to-back under the same machine state).
        ratios = sorted(d / f for f, d in zip(tf, td))
        tf.sort()
        td.sort()

        @jax.jit
        def indirect_call(data):
            st = ContextStore(lo, data)
            st = pems.alltoallv(st, "send", "recv", mode="indirect")
            return st.data

        # Same protocol as the direct paths (one warm call, then the same
        # sample count, median) so the Fig 7.2 direct-vs-indirect comparison
        # is not distorted by asymmetric sampling.
        jax.block_until_ready(indirect_call(store.data))
        ti = []
        for _ in range(iters * rounds):
            t0 = time.perf_counter()
            jax.block_until_ready(indirect_call(store.data))
            ti.append(time.perf_counter() - t0)
        ti.sort()
        # Median of the pooled interleaved samples as the primary statistic
        # (robust to load spikes on a shared box); mins reported alongside.
        us_fused = tf[len(tf) // 2] * 1e6
        us_dense = td[len(td) // 2] * 1e6
        us_indirect = ti[len(ti) // 2] * 1e6

        row = {
            "v": V,
            "omega": omega,
            "n_words": n_words,
            "direct_us": round(us_fused, 1),
            "direct_min_us": round(tf[0] * 1e6, 1),
            "direct_dense_us": round(us_dense, 1),
            "direct_dense_min_us": round(td[0] * 1e6, 1),
            "indirect_us": round(us_indirect, 1),
            "speedup_vs_dense": round(ratios[len(ratios) // 2], 3),
            "speedup_vs_dense_of_medians": round(us_dense / us_fused, 3),
            "speedup_vs_dense_min": round(td[0] / tf[0], 3),
        }

        for k in (1, 4):
            for mode in ("direct", "indirect"):
                base = Pems(PemsConfig(v=V, k=k), lo)
                st2 = base.init()
                base.alltoallv(st2, "send", "recv", mode=mode)
                io = base.ledger.io_total
                if mode == "direct":
                    t_model = analysis.pems2_alltoallv_seq_time(
                        V, k, lo.live_bytes, omega * 4, model)
                    us = us_fused
                else:
                    t_model = analysis.pems1_alltoallv_time(
                        V, lo.live_bytes, omega * 4, model)
                    us = us_indirect
                emit(f"alltoallv_{mode}_n{n_words}_k{k}", us,
                     f"io_bytes={io};model_time_blocks={t_model:.0f}")
                row[f"io_bytes_{mode}_k{k}"] = io
        configs.append(row)

    out = {
        "benchmark": "alltoallv_direct_delivery",
        "backend": jax.default_backend(),
        "v": V,
        "smoke": bool(smoke),
        "note": ("direct_us is the fused word-level kernel path; "
                 "direct_dense_us is the seed dense-transpose implementation "
                 "measured with the identical protocol, interleaved in the "
                 "same process"),
        "configs": configs,
    }
    # Smoke runs write to a separate file so CI / BENCH_FAST sweeps never
    # clobber the full-sweep deliverable at the repo root.
    name = "BENCH_alltoallv.smoke.json" if smoke else "BENCH_alltoallv.json"
    with open(os.path.join(REPO_ROOT, name), "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sweep for CI")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke or None)
