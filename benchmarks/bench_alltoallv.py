"""Fig 7.2 analogue: single EM-Alltoallv call, PEMS1-indirect vs PEMS2-direct,
k ∈ {1, 4}: wall time + ledger I/O + the thesis' analytic times.

Direct mode is additionally measured both ways through the collective layer:

* ``direct`` (the default path) — fused word-level delivery: slice the send
  word range, deliver (transpose + fused counts/boundary handling), rebuild
  the store row with a concatenate the delivery fuses into.
* ``direct_dense`` — the seed implementation (``use_kernel=False``): dense
  field gather → transpose → whole-store dynamic-update-slice.

Both are timed with the identical protocol (fresh output buffer per call,
as the seed benchmark did), interleaved iteration-by-iteration so machine
noise hits both equally; the comparison is written to
``BENCH_alltoallv.json`` at the repo root.

A ``P = 2`` sweep rides along (rows tagged ``"P": 2``): the same paired
fused-vs-dense comparison through the mesh network phase — the
(src_proc, dst_proc)-tiled assembly route vs ``_global_transpose``'s dense
staging — run in a subprocess with two fake CPU devices
(``--xla_force_host_platform_device_count`` must be set before jax
initialises, hence the subprocess).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp

from repro.core import ContextLayout, ContextStore, Pems, PemsConfig, analysis
from .common import emit

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
V = 16

_P2_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import json, time
import jax, jax.numpy as jnp
from repro.core import ContextLayout, ContextStore, Pems, PemsConfig

V, P = {v}, 2
mesh = jax.make_mesh((P,), ("vp",))
rows = []
for n_words in {sizes!r}:
    omega = n_words // (V * V)
    lo = (ContextLayout()
          .add("send", (V, omega), jnp.int32)
          .add("recv", (V, omega), jnp.int32))
    pems = Pems(PemsConfig(v=V, k=1, P=P), lo, mesh=mesh)
    store = pems.init()
    tf, td = [], []
    for _ in range({rounds}):
        @jax.jit
        def fused_call(data):
            st = ContextStore(lo, data)
            return pems.alltoallv(st, "send", "recv", mode="direct").data

        @jax.jit
        def dense_call(data):
            st = ContextStore(lo, data)
            return pems.alltoallv(st, "send", "recv", mode="direct",
                                  use_kernel=False).data

        data = jnp.array(store.data)
        jax.block_until_ready(fused_call(data))
        jax.block_until_ready(dense_call(data))
        for _ in range({iters}):
            t0 = time.perf_counter()
            jax.block_until_ready(fused_call(data))
            tf.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            jax.block_until_ready(dense_call(data))
            td.append(time.perf_counter() - t0)
    ratios = sorted(d / f for f, d in zip(tf, td))
    tf.sort()
    td.sort()
    # Ledger figures from a fresh executor and exactly one call — the
    # timing pems above accrues events at every retrace of both modes.
    led = Pems(PemsConfig(v=V, k=1, P=P), lo, mesh=mesh)
    led.alltoallv(led.init(), "send", "recv", mode="direct")
    rows.append({{
        "v": V,
        "P": P,
        "omega": omega,
        "n_words": n_words,
        "direct_us": round(tf[len(tf) // 2] * 1e6, 1),
        "direct_min_us": round(tf[0] * 1e6, 1),
        "direct_dense_us": round(td[len(td) // 2] * 1e6, 1),
        "direct_dense_min_us": round(td[0] * 1e6, 1),
        "speedup_vs_dense": round(ratios[len(ratios) // 2], 3),
        "speedup_vs_dense_of_medians": round(td[len(td) // 2] / tf[len(tf) // 2], 3),
        "speedup_vs_dense_min": round(td[0] / tf[0], 3),
        "io_bytes_direct_k1": led.ledger.io_total,
        "network_bytes": led.ledger.network,
    }})
print("P2JSON:" + json.dumps(rows))
"""


def _run_p2(sizes, iters, rounds):
    """Run the P=2 mesh sweep in a subprocess (fake CPU devices) and return
    its config rows.  Degrades to an empty list with a notice if the
    subprocess fails — the P=1 sweep is the primary deliverable."""
    script = _P2_SCRIPT.format(v=V, sizes=tuple(sizes), iters=iters,
                               rounds=rounds)
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        os.path.join(REPO_ROOT, "src")
        + os.pathsep + env.get("PYTHONPATH", "")
    )
    try:
        r = subprocess.run(
            [sys.executable, "-c", script],
            capture_output=True, text=True, timeout=1800, env=env,
            cwd=REPO_ROOT,
        )
    except (subprocess.TimeoutExpired, OSError) as e:
        print(f"# P=2 sweep failed, skipping: {e}", file=sys.stderr)
        return []
    for line in r.stdout.splitlines():
        if line.startswith("P2JSON:"):
            return json.loads(line[len("P2JSON:"):])
    print(f"# P=2 sweep failed, skipping: {r.stderr[-500:]}", file=sys.stderr)
    return []


def _interleaved_times(fused_fn, dense_fn, data, iters):
    """Time both paths back-to-back per iteration (identical protocol);
    returns paired (unsorted) seconds lists — consecutive samples share the
    machine state, so per-pair ratios cancel load drift."""
    jax.block_until_ready(fused_fn(data))                    # compile + warm
    jax.block_until_ready(dense_fn(data))
    tf, td = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fused_fn(data))
        tf.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(dense_fn(data))
        td.append(time.perf_counter() - t0)
    return tf, td


def run(smoke: bool | None = None) -> None:
    if smoke is None:
        smoke = os.environ.get("BENCH_FAST") == "1"
    sizes = (1 << 14, 1 << 16) if smoke else (1 << 14, 1 << 16, 1 << 18, 1 << 20)

    model = analysis.MachineModel(B=4096, S=1.0, G=1.0)
    configs = []
    for n_words in sizes:
        omega = n_words // (V * V)
        # Cheap configs get more samples: this box is noisy, and the robust
        # estimators below (paired-ratio and pooled medians) sharpen with
        # sample count.  Several rounds with fresh buffers/executables guard
        # against one unlucky allocation alignment dominating a process.
        iters = 6 if smoke else (100 if n_words <= 1 << 16 else 40)
        rounds = 1 if smoke else 3
        lo = (ContextLayout()
              .add("send", (V, omega), jnp.int32)
              .add("recv", (V, omega), jnp.int32))

        pems = Pems(PemsConfig(v=V, k=1), lo)
        store = pems.init()

        tf, td = [], []                        # all rounds' samples, pooled
        for _ in range(rounds):
            @jax.jit
            def fused_call(data):
                st = ContextStore(lo, data)
                st = pems.alltoallv(st, "send", "recv", mode="direct")
                return st.data

            @jax.jit
            def dense_call(data):
                st = ContextStore(lo, data)
                st = pems.alltoallv(st, "send", "recv", mode="direct",
                                    use_kernel=False)
                return st.data

            data = jnp.array(store.data)         # fresh buffer per round
            f, d = _interleaved_times(fused_call, dense_call, data, iters)
            tf.extend(f)
            td.extend(d)
        # Paired per-iteration ratios: the robust A/B statistic on a noisy
        # box (each pair ran back-to-back under the same machine state).
        ratios = sorted(d / f for f, d in zip(tf, td))
        tf.sort()
        td.sort()

        @jax.jit
        def indirect_call(data):
            st = ContextStore(lo, data)
            st = pems.alltoallv(st, "send", "recv", mode="indirect")
            return st.data

        # Same protocol as the direct paths (one warm call, then the same
        # sample count, median) so the Fig 7.2 direct-vs-indirect comparison
        # is not distorted by asymmetric sampling.
        jax.block_until_ready(indirect_call(store.data))
        ti = []
        for _ in range(iters * rounds):
            t0 = time.perf_counter()
            jax.block_until_ready(indirect_call(store.data))
            ti.append(time.perf_counter() - t0)
        ti.sort()
        # Median of the pooled interleaved samples as the primary statistic
        # (robust to load spikes on a shared box); mins reported alongside.
        us_fused = tf[len(tf) // 2] * 1e6
        us_dense = td[len(td) // 2] * 1e6
        us_indirect = ti[len(ti) // 2] * 1e6

        row = {
            "v": V,
            "P": 1,
            "omega": omega,
            "n_words": n_words,
            "direct_us": round(us_fused, 1),
            "direct_min_us": round(tf[0] * 1e6, 1),
            "direct_dense_us": round(us_dense, 1),
            "direct_dense_min_us": round(td[0] * 1e6, 1),
            "indirect_us": round(us_indirect, 1),
            "speedup_vs_dense": round(ratios[len(ratios) // 2], 3),
            "speedup_vs_dense_of_medians": round(us_dense / us_fused, 3),
            "speedup_vs_dense_min": round(td[0] / tf[0], 3),
        }

        for k in (1, 4):
            for mode in ("direct", "indirect"):
                base = Pems(PemsConfig(v=V, k=k), lo)
                st2 = base.init()
                base.alltoallv(st2, "send", "recv", mode=mode)
                io = base.ledger.io_total
                if mode == "direct":
                    t_model = analysis.pems2_alltoallv_seq_time(
                        V, k, lo.live_bytes, omega * 4, model)
                    us = us_fused
                else:
                    t_model = analysis.pems1_alltoallv_time(
                        V, lo.live_bytes, omega * 4, model)
                    us = us_indirect
                emit(f"alltoallv_{mode}_n{n_words}_k{k}", us,
                     f"io_bytes={io};model_time_blocks={t_model:.0f}")
                row[f"io_bytes_{mode}_k{k}"] = io
        configs.append(row)

    # P = 2 mesh sweep (fused assembly route vs dense staging), subprocess.
    p2_sizes = sizes[:2] if smoke else sizes
    p2_iters = 6 if smoke else 40
    p2_rounds = 1 if smoke else 3
    for row in _run_p2(p2_sizes, p2_iters, p2_rounds):
        emit(f"alltoallv_direct_P2_n{row['n_words']}_k1", row["direct_us"],
             f"speedup_vs_dense={row['speedup_vs_dense']}")
        configs.append(row)

    out = {
        "benchmark": "alltoallv_direct_delivery",
        "backend": jax.default_backend(),
        "v": V,
        "smoke": bool(smoke),
        "note": ("direct_us is the fused word-level kernel path; "
                 "direct_dense_us is the seed dense-transpose implementation "
                 "measured with the identical protocol, interleaved in the "
                 "same process; P=2 rows run the mesh network phase on two "
                 "fake CPU devices in a subprocess"),
        "configs": configs,
    }
    # Smoke runs write to a separate file so CI / BENCH_FAST sweeps never
    # clobber the full-sweep deliverable at the repo root.
    name = "BENCH_alltoallv.smoke.json" if smoke else "BENCH_alltoallv.json"
    with open(os.path.join(REPO_ROOT, name), "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sweep for CI")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke or None)
