"""Fig 7.2 analogue: single EM-Alltoallv call, PEMS1-indirect vs PEMS2-direct,
k ∈ {1, 4}: wall time + ledger I/O + the thesis' analytic times."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ContextLayout, Pems, PemsConfig, analysis
from .common import emit, time_fn


def run():
    model = analysis.MachineModel(B=4096, S=1.0, G=1.0)
    for n_words in (1 << 14, 1 << 16, 1 << 18):   # total payload words
        for k in (1, 4):
            v = 16
            omega = n_words // (v * v)
            lo = (ContextLayout()
                  .add("send", (v, omega), jnp.int32)
                  .add("recv", (v, omega), jnp.int32))
            for mode in ("direct", "indirect"):
                pems = Pems(PemsConfig(v=v, k=k), lo)
                store = pems.init()

                @jax.jit
                def call(data):
                    from repro.core import ContextStore
                    st = ContextStore(lo, data)
                    st = pems.alltoallv(st, "send", "recv", mode=mode)
                    return st.data

                us = time_fn(call, store.data)
                base = Pems(PemsConfig(v=v, k=k), lo)
                base.ledger = type(base.ledger)()
                st2 = base.init()
                base.alltoallv(st2, "send", "recv", mode=mode)
                io = base.ledger.io_total
                if mode == "direct":
                    t_model = analysis.pems2_alltoallv_seq_time(
                        v, k, lo.live_bytes, omega * 4, model)
                else:
                    t_model = analysis.pems1_alltoallv_time(
                        v, lo.live_bytes, omega * 4, model)
                emit(f"alltoallv_{mode}_n{n_words}_k{k}", us,
                     f"io_bytes={io};model_time_blocks={t_model:.0f}")
