"""§Roofline: the per-(arch × shape) roofline table from dry-run artifacts
(single-pod).  Run ``python -m repro.launch.dryrun --all`` first; cells with
no artifact are reported as missing rather than recomputed (compiling all 40
cells takes ~an hour on one CPU core)."""

from __future__ import annotations

import glob
import json
import os

from .common import emit

ART = os.environ.get("DRYRUN_ARTIFACTS", "artifacts/dryrun")


def run():
    files = sorted(glob.glob(os.path.join(ART, "*__single.json")))
    if not files:
        emit("roofline_missing", 0.0,
             f"no artifacts under {ART}; run repro.launch.dryrun first")
        return
    for fn in files:
        with open(fn) as f:
            d = json.load(f)
        if "error" in d:
            emit(f"roofline_{d['arch']}_{d['shape']}", 0.0, "ERROR")
            continue
        if "skipped" in d:
            continue
        c = d.get("calibrated")
        r = (c or d)["roofline"]
        uf = (c or d).get("useful_flop_ratio", 0.0)
        frac_opt = (c or {}).get(
            "roofline_fraction_optimistic", r["roofline_fraction"])
        emit(
            f"roofline_{d['arch']}_{d['shape']}",
            r["step_lower_bound_s"] * 1e6,
            f"compute_s={r['compute_s']:.4g};memory_s={r['memory_s']:.4g};"
            f"collective_s={r['collective_s']:.4g};dominant={r['dominant']};"
            f"fraction={r['roofline_fraction']:.3f};"
            f"fraction_optimistic={frac_opt:.3f};"
            f"useful_flops={uf:.3f};"
            f"fits_hbm={d['memory']['fits_hbm']};"
            f"calibrated={bool(c)}",
        )
