"""Benchmark utilities: wall-clock timing with warmup + CSV emission."""

from __future__ import annotations

import time
from typing import Callable

import jax


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time in microseconds (blocks on async dispatch)."""
    for _ in range(warmup):
        _block(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        _block(fn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def _block(out):
    for leaf in jax.tree.leaves(out):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()
    return out


def emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}")
