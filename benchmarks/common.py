"""Benchmark utilities: wall-clock timing with warmup + CSV emission.

All timing goes *through* :data:`TRACER` (the :mod:`repro.obs` span API):
a benchmark's reported number is the very span duration a trace export
would show, so the two can never disagree.  Per-bench scripts time their
phases with ``with TRACER.span(...) as sp: ...`` and read
``sp.duration_s`` instead of hand-rolling ``perf_counter()`` pairs.
"""

from __future__ import annotations

from typing import Callable

import jax

from repro.obs import Tracer

# Shared process-wide tracer for every bench script's timed regions.
TRACER = Tracer(name="bench")


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3,
            name: str = "bench") -> float:
    """Median wall time in microseconds (blocks on async dispatch)."""
    for _ in range(warmup):
        _block(fn(*args))
    times = []
    for _ in range(iters):
        with TRACER.span(name, tid="bench") as sp:
            _block(fn(*args))
        times.append(sp.duration_s * 1e6)
    times.sort()
    return times[len(times) // 2]


def _block(out):
    for leaf in jax.tree.leaves(out):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()
    return out


def emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}")
