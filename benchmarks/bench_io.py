"""repro.io engine sweep: driver × queue depth × block size, plus the
measured compute/I-O overlap each driver achieves under the async executor.

Two sections land in ``BENCH_io.json`` (``BENCH_io.smoke.json`` under
``BENCH_FAST=1``/``--smoke``):

* ``engine`` — raw submission-queue throughput: write a file once in
  ``block_bytes`` requests at ``queue_depth`` in flight, fsync, read it
  back, verify.  ``mb_s`` per direction, plus the measured
  ``max_queue_depth`` and syscall byte counts (the ``odirect`` rows show
  the aligned inflation; ``odirect_fallback`` records whether the
  filesystem actually honoured O_DIRECT or the documented buffered
  fallback was taken).
* ``psrs`` — PSRS on ``tier="file"`` per driver, sync vs async executor:
  ``overlap_fraction`` (share of swap-in time hidden behind compute,
  thesis §5.1) and ``rw_overlap_events`` (submissions that saw the
  opposite direction in flight — the async engine keeps round ``r+1``'s
  reads AND round ``r-1``'s writeback in flight during round ``r``).

The regression gate (``scripts/check_bench_regression.py``) compares the
``psrs`` rows' overlap fractions against the committed smoke baseline and
skips ``odirect`` rows whose fallback status differs from the baseline's
(a CI filesystem without O_DIRECT must take the fallback, not fail).
"""

from __future__ import annotations

import json
import os
import sys
import tempfile

import jax
import numpy as np

from repro.io import IOEngine, open_file
from repro.pems_apps import psrs_sort
from .common import TRACER, emit

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DRIVERS = ("buffered", "odirect", "mmap")


def _engine_row(td: str, driver: str, queue_depth: int, block_bytes: int,
                file_bytes: int, rng) -> dict:
    path = os.path.join(td, f"{driver}_{queue_depth}_{block_bytes}.bin")
    f = open_file(path, file_bytes, driver)
    eng = IOEngine(f, queue_depth=queue_depth)
    data = rng.integers(0, 256, file_bytes, dtype=np.uint8)
    try:
        with TRACER.span(f"engine_write_{driver}", tid="bench") as sp:
            for o in range(0, file_bytes, block_bytes):
                eng.submit_write(o, data[o:o + block_bytes])
            eng.fsync()
        w_s = sp.duration_s

        out = np.empty(file_bytes, np.uint8)
        with TRACER.span(f"engine_read_{driver}", tid="bench") as sp:
            for o in range(0, file_bytes, block_bytes):
                eng.submit_read(o, out[o:o + block_bytes])
            eng.drain()
        r_s = sp.duration_s
        data_ok = bool((out == data).all())
        row = {
            "driver": driver,
            "fallback": bool(getattr(f, "fallback", False)),
            "queue_depth": queue_depth,
            "block_bytes": block_bytes,
            "file_bytes": file_bytes,
            "write_mb_s": round(file_bytes / w_s / 1e6, 1),
            "read_mb_s": round(file_bytes / r_s / 1e6, 1),
            "max_queue_depth": eng.max_queue_depth,
            "queue_stall_s": round(eng.queue_stall_s, 4),
            "fsyncs": eng.fsyncs,
            "syscall_read_bytes": eng.syscall_read_bytes,
            "syscall_write_bytes": eng.syscall_write_bytes,
            "data_ok": data_ok,
        }
    finally:
        eng.close()
        os.unlink(path)
    assert row["data_ok"], f"round-trip mismatch: {driver}"
    return row


def _psrs_row(td: str, driver: str, exec_driver: str, keys, v: int, k: int,
              queue_depth: int, want, checksums: bool = False) -> dict:
    tag = f"psrs_{driver}_{exec_driver}{'_crc' if checksums else ''}.bin"
    with TRACER.span(f"psrs_{driver}_{exec_driver}", tid="bench") as sp:
        out, pems = psrs_sort(
            keys, v=v, k=k, driver=exec_driver, tier="file",
            io_driver=driver, io_queue_depth=queue_depth,
            checksums=checksums, backing_path=os.path.join(td, tag),
            return_pems=True,
        )
    wall_s = sp.duration_s
    assert (out == want).all(), f"file-tier sort diverged: {driver}"
    led, ts = pems.ledger, pems.tier_stats
    fallback = bool(getattr(getattr(pems.backing, "file", None),
                            "fallback", False))
    return {
        "io_driver": driver,
        "exec_driver": exec_driver,
        "checksum": checksums,
        "fallback": fallback,
        "n": int(np.asarray(keys).size),
        "v": v,
        "k": k,
        "queue_depth": queue_depth,
        "wall_s": round(wall_s, 3),
        "disk_read_bytes": led.disk_read_bytes,
        "disk_write_bytes": led.disk_write_bytes,
        "syscall_read_bytes": led.syscall_read_bytes,
        "syscall_write_bytes": led.syscall_write_bytes,
        "max_queue_depth": ts.max_queue_depth,
        "queue_stall_s": round(ts.queue_stall_s, 4),
        "rw_overlap_events": ts.rw_overlap_events,
        "overlap_fraction": round(ts.overlap_fraction, 4),
    }


def run(smoke: bool | None = None) -> None:
    if smoke is None:
        smoke = os.environ.get("BENCH_FAST") == "1"
    rng = np.random.default_rng(7)

    if smoke:
        depths = (1, 8)
        blocks = (256 << 10,)
        file_bytes = 8 << 20
        n, v, k = 1 << 17, 16, 2
    else:
        depths = (1, 4, 16)
        blocks = (64 << 10, 1 << 20)
        file_bytes = 64 << 20
        n, v, k = 1 << 20, 16, 2

    engine_rows = []
    psrs_rows = []
    odirect_fallback = False
    with tempfile.TemporaryDirectory() as td:
        for driver in DRIVERS:
            for qd in depths:
                for blk in blocks:
                    row = _engine_row(td, driver, qd, blk, file_bytes, rng)
                    engine_rows.append(row)
                    if driver == "odirect":
                        odirect_fallback = row["fallback"]
                    emit(f"io_{driver}_qd{qd}_blk{blk}", 0.0,
                         f"write_mb_s={row['write_mb_s']};"
                         f"read_mb_s={row['read_mb_s']};"
                         f"depth={row['max_queue_depth']}")

        keys = rng.integers(-2**31, 2**31 - 1, size=n, dtype=np.int32)
        want = np.sort(keys)
        qd = max(depths)
        for driver in DRIVERS:
            for exec_driver in ("explicit", "async"):
                row = _psrs_row(td, driver, exec_driver, keys, v, k, qd,
                                want)
                psrs_rows.append(row)
                emit(f"io_psrs_{driver}_{exec_driver}", row["wall_s"] * 1e6,
                     f"overlap={row['overlap_fraction']};"
                     f"rw_overlap={row['rw_overlap_events']}")

        # Integrity cost: a checksum-on row measured *paired* against a
        # checksum-off twin — interleaved, min-of-2 per side, so jit and
        # page-cache noise cancels and the regression gate can hold the
        # per-block CRC sidecar's overhead to a tight bound.
        offs, ons, row = [], [], None
        for rep in range(2):
            offs.append(_psrs_row(td, driver="buffered",
                                  exec_driver="async", keys=keys, v=v, k=k,
                                  queue_depth=qd, want=want)["wall_s"])
            row = _psrs_row(td, driver="buffered", exec_driver="async",
                            keys=keys, v=v, k=k, queue_depth=qd, want=want,
                            checksums=True)
            ons.append(row["wall_s"])
        row["wall_s"] = min(ons)
        row["wall_plain_s"] = min(offs)
        row["checksum_overhead"] = round(min(ons) / min(offs) - 1, 4)
        psrs_rows.append(row)
        emit("io_psrs_buffered_async_crc", row["wall_s"] * 1e6,
             f"overhead={row['checksum_overhead']};"
             f"overlap={row['overlap_fraction']}")

    out = {
        "benchmark": "io_engine",
        "backend": jax.default_backend(),
        "smoke": bool(smoke),
        "odirect_fallback": odirect_fallback,
        "note": ("engine rows: one full-file write + fsync + read-back per "
                 "(driver, queue_depth, block_bytes).  psrs rows: PSRS on "
                 "tier='file'; overlap_fraction = 1 - stall_s/swap_in_s; "
                 "rw_overlap_events > 0 on the async rows means swap-in "
                 "reads and writeback writes were simultaneously in flight "
                 "(both directions, §5.1).  checksum=true rows run the same "
                 "sort with the per-block CRC sidecar on; their wall_s vs "
                 "the checksum=false twin is the integrity overhead the "
                 "gate bounds."),
        "engine": engine_rows,
        "psrs": psrs_rows,
    }
    name = "BENCH_io.smoke.json" if smoke else "BENCH_io.json"
    with open(os.path.join(REPO_ROOT, name), "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")

    best = max(r["overlap_fraction"] for r in psrs_rows
               if r["exec_driver"] == "async")
    emit("io_psrs_async_best_overlap", 0.0, f"overlap_fraction={best}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(smoke="--smoke" in sys.argv or None)
