"""Figs 8.15–8.20 analogue: CGM applications (sample sort + prefix sum)
scaling, per driver."""

from __future__ import annotations

import numpy as np

from repro.pems_apps import prefix_sum, psrs_sort
from .common import emit, time_fn


def run():
    rng = np.random.default_rng(3)
    for n in (1 << 16, 1 << 18):
        x = rng.integers(-2**31, 2**31 - 1, size=n, dtype=np.int32)
        for driver in ("explicit", "sliced"):
            us = time_fn(
                lambda d=driver: psrs_sort(x, v=8, k=2, driver=d), iters=1)
            emit(f"cgm_sort_{driver}_n{n}", us, "")
        xp = rng.integers(-100, 100, size=n, dtype=np.int32)
        for driver in ("explicit", "sliced"):
            us = time_fn(
                lambda d=driver: prefix_sum(xp, v=8, k=2, driver=d), iters=1)
            emit(f"cgm_prefix_{driver}_n{n}", us, "")
