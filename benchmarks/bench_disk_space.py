"""Fig 6.2: disk-space requirements, PEMS1 vs PEMS2 (exact table) — plus the
real thing: a memmap-backed store's file on disk, created sparse at exactly
vμ (§6.3), with allocated blocks growing only as live ranges are touched."""

from __future__ import annotations

import os
import tempfile

import jax.numpy as jnp

from repro.core import ContextLayout, Pems, PemsConfig, WORD, analysis
from .common import emit


def run():
    GiB = 1024 ** 3
    for (P, v, req, p1p, p1t, p2p, p2t) in analysis.disk_space_table(
            8, 2 * GiB):
        emit(f"disk_space_P{P}", 0.0,
             f"v={v};required={req // GiB}GiB;pems1_per_proc={p1p // GiB}GiB;"
             f"pems1_total={p1t // GiB}GiB;pems2_per_proc={p2p // GiB}GiB;"
             f"pems2_total={p2t // GiB}GiB")

    # Real backing file: vμ on disk, sparse until the swap engine touches it.
    v, k, capacity = 16, 4, 1 << 16            # μ = 256 KiB, vμ = 4 MiB
    lo = (ContextLayout(capacity_words=capacity)
          .add("data", (1 << 14,), jnp.int32)  # only 1/4 of μ is live
          .add("acc", (1 << 14,), jnp.int32))
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "ctx.bin")
        pems = Pems(PemsConfig(v=v, k=k, tier="memmap", backing_path=path),
                    lo)
        store = pems.init()
        size0, blocks0 = _stat(path)
        store = pems.superstep(
            store, lambda rho, c: c.set("acc", c.get("data") + rho))
        store.flush()
        size1, blocks1 = _stat(path)
        led = pems.ledger
        emit("disk_space_memmap_real", 0.0,
             f"file_bytes={size1};required={v * capacity * WORD};"
             f"allocated_before={blocks0};allocated_after={blocks1};"
             f"live_fraction={lo.live_words / lo.words:.2f};"
             f"ledger_disk_read={led.disk_read_bytes};"
             f"ledger_disk_write={led.disk_write_bytes}")
        assert size1 == v * capacity * WORD
        assert led.disk_write_bytes == v * lo.live_words * WORD


def _stat(path):
    st = os.stat(path)
    return st.st_size, st.st_blocks * 512


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
