"""Fig 6.2: disk-space requirements, PEMS1 vs PEMS2 (exact table)."""

from __future__ import annotations

from repro.core import analysis
from .common import emit


def run():
    GiB = 1024 ** 3
    for (P, v, req, p1p, p1t, p2p, p2t) in analysis.disk_space_table(
            8, 2 * GiB):
        emit(f"disk_space_P{P}", 0.0,
             f"v={v};required={req // GiB}GiB;pems1_per_proc={p1p // GiB}GiB;"
             f"pems1_total={p1t // GiB}GiB;pems2_per_proc={p2p // GiB}GiB;"
             f"pems2_total={p2t // GiB}GiB")
