"""Fig 8.7 analogue: growing context size μ at constant v.  PEMS1's indirect
area grows with v·μ and its I/O with 4vμ; PEMS2's with vμ — the gap widens
with μ (on spinning disks the seek distance amplified this further)."""

from __future__ import annotations

import numpy as np

from repro.pems_apps import psrs_sort
from .common import emit, time_fn


def run():
    rng = np.random.default_rng(1)
    v, k = 8, 2
    for n in (1 << 16, 1 << 18, 1 << 20):      # μ grows with n at constant v
        x = rng.integers(-2**31, 2**31 - 1, size=n, dtype=np.int32)
        row = {}
        for mode in ("direct", "indirect"):
            us = time_fn(lambda m=mode: psrs_sort(x, v=v, k=k, mode=m),
                         iters=1)
            _, pems = psrs_sort(x, v=v, k=k, mode=mode, return_pems=True)
            row[mode] = (us, pems.ledger)
        mu = row["direct"][1].disk_space // v
        emit(f"psrs_mu_direct_n{n}", row["direct"][0],
             f"mu_bytes={mu};io={row['direct'][1].io_total}")
        emit(f"psrs_mu_indirect_n{n}", row["indirect"][0],
             f"mu_bytes={mu};io={row['indirect'][1].io_total};"
             f"disk={row['indirect'][1].disk_space}")
