"""Deterministic, seed-driven fault injection below the I/O engine.

``FaultyFile`` wraps any positional driver (``buffered``/``odirect``/``mmap``)
behind the same ``pread_into``/``pwrite`` API, so the whole stack above it —
engine retries, backing-tier checksums, superstep recovery — exercises real
failure paths without real hardware faults.  Select it with
``PemsConfig(io_driver="faulty:<inner>", fault_spec=...)`` or
``open_file(..., "faulty:<inner>", fault_spec=...)``.

Fault-spec grammar (semicolon-separated clauses)::

    spec   := clause (";" clause)*
    clause := "seed=" N | "shard=" N | kind "@" sel [":" param]
    kind   := "eio" | "torn" | "lat" | "enospc" | "kill"
    sel    := [op] ("*" | N | N "-" M | "p" FLOAT | "b" LO "-" HI)
    op     := "w" | "r"              -- restrict to writes / reads

``shard=N`` is not a fault of its own: under a sharded backing (``P > 1``,
one backing file + driver per mesh process) it restricts the *whole spec* to
shard ``N``'s driver — the other shards run the clean inner driver, the
single-disk-failure model.  It is stripped by :func:`split_shard_clause`
before parsing; with no ``shard=`` clause the spec applies to every shard
(and at ``P == 1`` to the only one).  Byte-range (``b``) selectors address
offsets within the *shard's own* file.

Selectors address driver-level request *attempts* (engine retries re-count),
either by per-op index (``w3``, ``r0-4``), by overall match (``*``), by a
seeded pseudo-random probability (``p0.02`` — deterministic in
``(seed, op, index)``), or by file byte range overlap (``b0-65535``).

Per-kind parameter:

* ``eio``: ``xK`` — the matching request fails ``K`` consecutive attempts
  with ``EIO`` before succeeding (default 1), so bounded engine retries can
  be proven to absorb it (or to exhaust).
* ``torn``: fraction of the payload actually written, default ``0.5``.
  Torn writes are **silent** — the driver reports full success, exactly like
  a power cut after a partial sector flush; only checksums can catch them.
* ``lat``: seconds of injected latency, default ``0.001``.
* ``enospc``: no parameter; raises ``ENOSPC`` (permanent — never retried).
* ``kill``: no parameter; ``SIGKILL``s the *process* at the matching request,
  i.e. genuine mid-I/O death for crash-recovery tests.

Example: ``"seed=7;eio@p0.02:x2;lat@p0.01:0.003;torn@w44"``.

Indices count every attempt the engine issues, so under ``queue_depth > 1``
the mapping from index to logical request depends on scheduling; tests that
need exact determinism use ``queue_depth=1`` or byte-range selectors.
"""

from __future__ import annotations

import errno
import os
import re
import signal
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.obs import NOOP

_KINDS = ("eio", "torn", "lat", "enospc", "kill")

_SEL_RE = re.compile(
    r"^(?P<op>[wr])?(?:(?P<star>\*)|p(?P<prob>[0-9.]+)"
    r"|b(?P<blo>\d+)-(?P<bhi>\d+)|(?P<lo>\d+)(?:-(?P<hi>\d+))?)$"
)


def split_shard_clause(spec: Optional[str]):
    """Strip the optional ``shard=N`` clause out of a fault spec.

    Returns ``(shard, rest)`` — ``shard`` is the targeted shard index (or
    ``None`` when the spec names no shard, meaning "every shard") and
    ``rest`` is the spec with the clause removed, ready for
    :meth:`FaultSpec.parse`.  The sharded backing hands ``rest`` only to
    shard ``shard``'s driver; all other shards get the clean inner driver.
    """
    if not spec:
        return None, spec
    shard = None
    keep = []
    for raw in spec.split(";"):
        s = raw.strip()
        if s.startswith("shard="):
            try:
                shard = int(s[6:])
            except ValueError:
                raise ValueError(f"bad fault_spec shard clause {s!r}")
            if shard < 0:
                raise ValueError(f"fault_spec shard index must be >= 0: {s!r}")
            continue
        if s:
            keep.append(s)
    return shard, ";".join(keep)


@dataclass
class _Clause:
    kind: str
    op: Optional[str] = None            # "read" | "write" | None
    lo: Optional[int] = None            # request-index range (inclusive)
    hi: Optional[int] = None
    prob: Optional[float] = None
    byte_lo: Optional[int] = None       # file byte range (inclusive)
    byte_hi: Optional[int] = None
    param: float = 0.0


@dataclass
class FaultSpec:
    """Parsed fault specification: a seed plus an ordered clause list."""

    seed: int = 0
    clauses: List[_Clause] = field(default_factory=list)

    @staticmethod
    def parse(spec: Optional[str]) -> "FaultSpec":
        out = FaultSpec()
        if not spec:
            return out
        for raw in spec.split(";"):
            raw = raw.strip()
            if not raw:
                continue
            if raw.startswith("seed="):
                try:
                    out.seed = int(raw[5:])
                except ValueError:
                    raise ValueError(f"bad fault_spec seed clause {raw!r}")
                continue
            if "@" not in raw:
                raise ValueError(
                    f"bad fault_spec clause {raw!r}: expected "
                    "'kind@sel[:param]' or 'seed=N'")
            kind, rest = raw.split("@", 1)
            if kind not in _KINDS:
                raise ValueError(
                    f"bad fault_spec kind {kind!r} in {raw!r}: "
                    f"one of {_KINDS}")
            sel, _, param = rest.partition(":")
            m = _SEL_RE.match(sel)
            if not m:
                raise ValueError(
                    f"bad fault_spec selector {sel!r} in {raw!r}: expected "
                    "[w|r](* | N | N-M | pFLOAT | bLO-HI)")
            cl = _Clause(kind=kind)
            if m.group("op"):
                cl.op = "write" if m.group("op") == "w" else "read"
            if m.group("prob") is not None:
                try:
                    cl.prob = float(m.group("prob"))
                except ValueError:
                    raise ValueError(f"bad probability in {raw!r}")
                if not 0.0 <= cl.prob <= 1.0:
                    raise ValueError(f"probability out of [0,1] in {raw!r}")
            elif m.group("blo") is not None:
                cl.byte_lo = int(m.group("blo"))
                cl.byte_hi = int(m.group("bhi"))
            elif m.group("lo") is not None:
                cl.lo = int(m.group("lo"))
                cl.hi = int(m.group("hi") or m.group("lo"))
            # else: "*" matches everything
            if cl.kind == "eio":
                cl.param = 1.0
                if param:
                    if not re.fullmatch(r"x\d+", param):
                        raise ValueError(
                            f"bad eio param {param!r} in {raw!r}: expected "
                            "xK (consecutive failing attempts)")
                    cl.param = float(param[1:])
            elif cl.kind == "torn":
                cl.param = float(param) if param else 0.5
                if not 0.0 < cl.param <= 1.0:
                    raise ValueError(
                        f"torn fraction out of (0,1] in {raw!r}")
                cl.op = "write"         # torn reads are meaningless
            elif cl.kind == "lat":
                cl.param = float(param) if param else 1e-3
                if cl.param < 0:
                    raise ValueError(f"negative latency in {raw!r}")
            elif param:
                raise ValueError(
                    f"kind {kind!r} takes no parameter (got {param!r})")
            out.clauses.append(cl)
        return out


def _hash01(seed: int, op: str, idx: int, salt: int) -> float:
    """Deterministic uniform [0,1) from (seed, op, index, clause)."""
    h = (seed * 1000003) ^ (0x9E3779B9 if op == "write" else 0x85EBCA77)
    h ^= (idx * 2654435761) ^ (salt * 40503)
    h = (h * 6364136223846793005 + 1442695040888963407) & (2 ** 64 - 1)
    return (h >> 11) / float(2 ** 53)


class FaultyFile:
    """Driver proxy injecting faults per :class:`FaultSpec`.

    Sits *below* the engine: every injected ``OSError`` flows through the
    engine's retry/propagation machinery, every torn write is only visible
    to the checksum layer, and ``kill`` dies with I/O genuinely in flight.
    ``injected`` counts faults by kind for assertions and reporting.
    """

    # repro.obs tracing (attached post-construction by the executor): each
    # injected fault is an instant event on the owning shard's lane, so a
    # trace answers "which injection caused this retry/stall".
    tracer = NOOP

    def __init__(self, inner, spec: FaultSpec):
        self.inner = inner
        self.spec = spec
        self._lock = threading.Lock()
        self._n = {"read": 0, "write": 0}
        self.injected: Dict[str, int] = {k: 0 for k in _KINDS}
        # (clause idx, op, offset) -> remaining consecutive eio failures
        self._armed: Dict[Tuple[int, str, int], int] = {}

    # ------------------------------------------------------------- delegation
    @property
    def path(self):
        return self.inner.path

    @property
    def align(self):
        return self.inner.align

    @property
    def driver(self):
        return f"faulty:{self.inner.driver}"

    @property
    def fallback(self):
        return getattr(self.inner, "fallback", False)

    def flush(self):
        return self.inner.flush()

    def close(self):
        return self.inner.close()

    # -------------------------------------------------------------- injection
    def _apply(self, op: str, offset: int, nbytes: int) -> Optional[float]:
        """Evaluate clauses; raise/sleep/kill as matched.

        Returns a torn-write fraction, or None for a clean pass-through.
        """
        sleep_s = 0.0
        torn: Optional[float] = None
        with self._lock:
            idx = self._n[op]
            self._n[op] = idx + 1
            fire: List[_Clause] = []
            for ci, cl in enumerate(self.spec.clauses):
                key = (ci, op, offset)
                if cl.kind == "eio" and self._armed.get(key, 0) > 0:
                    self._armed[key] -= 1
                    if self._armed[key] == 0:
                        del self._armed[key]
                    fire.append(cl)
                    continue
                if cl.op is not None and cl.op != op:
                    continue
                if cl.lo is not None and not cl.lo <= idx <= cl.hi:
                    continue
                if cl.byte_lo is not None and not (
                        offset <= cl.byte_hi and offset + nbytes > cl.byte_lo):
                    continue
                if cl.prob is not None and _hash01(
                        self.spec.seed, op, idx, ci) >= cl.prob:
                    continue
                if cl.kind == "eio" and cl.param > 1 and key not in self._armed:
                    # Arm the remaining K-1 consecutive failures for the
                    # engine's retries of this same (op, offset) to consume.
                    self._armed[key] = int(cl.param) - 1
                fire.append(cl)
            for cl in fire:
                self.injected[cl.kind] += 1
                if cl.kind == "lat":
                    sleep_s += cl.param
                elif cl.kind == "torn":
                    torn = cl.param if torn is None else min(torn, cl.param)
        # Effects outside the lock so concurrent workers aren't serialised.
        if self.tracer.enabled:
            for cl in fire:
                self.tracer.instant(f"fault:{cl.kind}", tid="events",
                                    cat="fault", op=op, offset=offset,
                                    nbytes=nbytes)
        if sleep_s:
            time.sleep(sleep_s)
        for cl in fire:
            if cl.kind == "kill":
                os.kill(os.getpid(), signal.SIGKILL)
            if cl.kind == "enospc":
                raise OSError(
                    errno.ENOSPC,
                    f"injected ENOSPC: {op} of {nbytes:,} bytes at offset "
                    f"{offset:,} on {self.path!r} (fault_spec)")
            if cl.kind == "eio":
                raise OSError(
                    errno.EIO,
                    f"injected EIO: {op} of {nbytes:,} bytes at offset "
                    f"{offset:,} on {self.path!r} (fault_spec)")
        return torn

    # --------------------------------------------------------------- file API
    def pread_into(self, offset: int, out) -> int:
        nbytes = memoryview(out).nbytes
        self._apply("read", offset, nbytes)
        return self.inner.pread_into(offset, out)

    def pwrite(self, offset: int, data) -> int:
        buf = np.ascontiguousarray(data).view(np.uint8).reshape(-1)
        nbytes = buf.nbytes
        torn = self._apply("write", offset, nbytes)
        if torn is not None and nbytes > 1:
            # Silent short write: persist only a prefix but report success —
            # the power-cut model.  Detection is the checksum layer's job.
            keep = max(1, int(nbytes * torn))
            self.inner.pwrite(offset, buf[:keep])
            return nbytes
        return self.inner.pwrite(offset, data)
