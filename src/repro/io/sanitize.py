"""Runtime in-flight I/O race sanitizer: ``io_driver="sanitize:<inner>"``.

The static ``submit-then-mutate`` pems-lint rule catches the lexical shape
of the hazard; this wrapper catches it dynamically.  ``SanitizingFile``
wraps any driver (same proxy shape as :class:`repro.io.faults.FaultyFile`)
and the :class:`~repro.io.engine.IOEngine` feeds it two duck-typed hooks:

* ``note_submit(req)`` — records the request's byte range and, for writes,
  a CRC of the buffer *as submitted*, plus the submitting stack.  A new
  range overlapping one already in flight (either side a write) is an
  **overlap** finding: the engine only serialises aligned-range conflicts
  for ``align > 1`` drivers, so unserialized overlapping writes race.
* ``note_complete(req)`` — re-CRCs the write buffer the worker actually
  transferred.  A mismatch means the caller mutated the buffer between
  submit and completion — a **mutate-in-flight** finding carrying the
  submitting stack, which names the culprit call site.

Findings accumulate on ``SanitizingFile.findings`` (thread-safe) and are
never raised mid-run — chaos/regression suites assert the list is empty
(or not, for planted races) after ``drain``.  Overhead is one CRC per
write at submit + completion and a stack capture per request: enable it in
tests and chaos runs, not production benches (see docs/TUNING.md).

Compose wrappers left to right: ``"sanitize:faulty:buffered"`` sanitizes
above the fault injector.
"""

from __future__ import annotations

import threading
import traceback
import zlib
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.obs import NOOP

__all__ = ["SanitizeFinding", "SanitizingFile", "collect_findings"]


def _crc(buf) -> int:
    arr = np.ascontiguousarray(buf)
    return zlib.crc32(memoryview(arr).cast("B"))


def _submit_stack() -> str:
    # Drop this helper, note_submit, and the engine's _submit frame — the
    # interesting tail is the caller that handed the buffer over.
    frames = traceback.format_stack()[:-3]
    return "".join(frames[-6:])


@dataclass
class SanitizeFinding:
    """One detected race.  ``kind`` is ``"overlap"`` (two in-flight
    requests on intersecting byte ranges, at least one a write) or
    ``"mutate-in-flight"`` (a write buffer changed between submit and
    completion).  ``stack`` is the submitting call stack of the offending
    request."""

    kind: str
    op: str
    offset: int
    nbytes: int
    path: Optional[str]
    detail: str
    stack: str

    def format(self) -> str:
        """Multi-line human-readable report of this finding."""
        return (f"sanitize: {self.kind}: {self.op} of {self.nbytes:,} B at "
                f"offset {self.offset:,} on {self.path!r}: {self.detail}\n"
                f"submitted at:\n{self.stack}")


class _Track:
    __slots__ = ("op", "lo", "hi", "crc", "stack")

    def __init__(self, op: str, lo: int, hi: int, crc: Optional[int],
                 stack: str):
        self.op = op
        self.lo = lo
        self.hi = hi
        self.crc = crc
        self.stack = stack


class SanitizingFile:
    """Driver proxy recording in-flight ranges and write-buffer CRCs.

    Pure pass-through on the data path (``pread_into``/``pwrite`` delegate
    untouched); all detection happens in the ``note_submit``/
    ``note_complete`` hooks the engine calls around a request's lifetime.
    ``tracked`` counts requests observed (proof the sanitizer was live);
    ``findings`` holds :class:`SanitizeFinding` records.
    """

    # repro.obs tracing (attached post-construction by the executor): each
    # finding doubles as an instant event, so a trace timeline shows *when*
    # the race was detected relative to the spans around it.
    tracer = NOOP

    def __init__(self, inner):
        self.inner = inner
        self._lock = threading.Lock()
        self._inflight: Dict[int, _Track] = {}
        self.findings: List[SanitizeFinding] = []
        self.tracked = 0

    # ------------------------------------------------------------- delegation
    @property
    def path(self):
        return self.inner.path

    @property
    def align(self):
        return self.inner.align

    @property
    def driver(self):
        return f"sanitize:{self.inner.driver}"

    @property
    def fallback(self):
        return getattr(self.inner, "fallback", False)

    def flush(self):
        return self.inner.flush()

    def close(self):
        return self.inner.close()

    def pread_into(self, offset: int, out) -> int:
        return self.inner.pread_into(offset, out)

    def pwrite(self, offset: int, data) -> int:
        return self.inner.pwrite(offset, data)

    # ------------------------------------------------------------------ hooks
    def note_submit(self, req) -> None:
        """Engine hook: called under the engine lock once ``req`` joins the
        in-flight set (after any aligned-conflict serialisation, so ranges
        the engine serialises never co-exist here)."""
        lo, hi = req.offset, req.offset + req.nbytes
        crc = (_crc(req.data)
               if req.op == "write" and req.data is not None else None)
        stack = _submit_stack()
        hit = False
        with self._lock:
            for t in self._inflight.values():
                if t.lo < hi and lo < t.hi and "write" in (t.op, req.op):
                    hit = True
                    self.findings.append(SanitizeFinding(
                        kind="overlap", op=req.op, offset=req.offset,
                        nbytes=req.nbytes, path=self.path,
                        detail=(f"byte range [{lo:,}, {hi:,}) overlaps the "
                                f"in-flight {t.op} [{t.lo:,}, {t.hi:,}) — "
                                "unserialized overlapping requests race; "
                                "wait/drain between them"),
                        stack=stack))
            self._inflight[id(req)] = _Track(req.op, lo, hi, crc, stack)
            self.tracked += 1
        if hit and self.tracer.enabled:
            self.tracer.instant("sanitize:overlap", tid="events",
                                cat="sanitize", op=req.op,
                                offset=req.offset, nbytes=req.nbytes)

    def note_complete(self, req) -> None:
        """Engine hook: called from the worker after the driver op, while
        ``req.data`` is still held — the submit-time CRC is checked against
        the bytes the worker actually saw."""
        with self._lock:
            t = self._inflight.pop(id(req), None)
        if t is None or t.crc is None or req.data is None:
            return
        if _crc(req.data) != t.crc:
            f = SanitizeFinding(
                kind="mutate-in-flight", op=req.op, offset=req.offset,
                nbytes=req.nbytes, path=self.path,
                detail=("write buffer changed between submit and "
                        "completion — the caller mutated (or reused) the "
                        "buffer while the request was in flight"),
                stack=t.stack)
            with self._lock:
                self.findings.append(f)
            self.tracer.instant("sanitize:mutate-in-flight", tid="events",
                                cat="sanitize", op=req.op,
                                offset=req.offset, nbytes=req.nbytes)

    # ---------------------------------------------------------------- reports
    def format_findings(self) -> str:
        """All findings as one human-readable block (empty string if
        clean)."""
        with self._lock:
            return "\n".join(f.format() for f in self.findings)


def collect_findings(backing) -> List[SanitizeFinding]:
    """Every sanitizer finding reachable from a backing: its own driver
    file (``backing.file``) and, for a sharded backing, each shard's.
    Backings without a sanitizing driver contribute nothing."""
    out: List[SanitizeFinding] = []
    for bk in getattr(backing, "shards", None) or [backing]:
        f = getattr(bk, "file", None)
        out.extend(getattr(f, "findings", ()))
    return out
