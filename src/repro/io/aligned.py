"""Aligned, reusable host buffers for O_DIRECT transfers.

O_DIRECT requires the user buffer, the file offset, and the transfer length
to all be aligned to the device's logical block size.  numpy gives no
alignment guarantee, so :func:`aligned_empty` over-allocates and slices to a
4 KiB boundary, and :class:`AlignedPool` recycles those buffers across
requests — the engine's workers acquire/release per transfer instead of
allocating, exactly the reusable-buffer structure of STXXL-style engines.
"""

from __future__ import annotations

import threading
from typing import Dict, List

import numpy as np

ALIGN = 4096   # conservative logical block size (covers 512e and 4Kn disks)


def align_down(x: int, align: int = ALIGN) -> int:
    return x - (x % align)


def align_up(x: int, align: int = ALIGN) -> int:
    return x + (-x % align)


def aligned_empty(nbytes: int, align: int = ALIGN) -> np.ndarray:
    """An uninitialised uint8 buffer whose data pointer is ``align``-aligned
    (and whose length is an exact multiple of ``align``)."""
    nbytes = align_up(max(nbytes, 1), align)
    try:
        raw = np.empty(nbytes + align, np.uint8)
    except MemoryError as e:
        raise MemoryError(
            f"cannot allocate a {nbytes + align:,}-byte aligned I/O bounce "
            "buffer (O_DIRECT pool); lower io_queue_depth or the transfer "
            "chunk size, or free host memory") from e
    off = (-raw.ctypes.data) % align
    buf = raw[off:off + nbytes]
    assert buf.ctypes.data % align == 0
    return buf


class AlignedPool:
    """Thread-safe free list of aligned buffers, bucketed by size.

    ``acquire`` returns a buffer of at least ``nbytes`` (rounded up to the
    alignment); ``release`` returns it for reuse.  The pool holds at most
    ``max_per_size`` free buffers per size class so a queue-depth burst does
    not pin memory forever.
    """

    def __init__(self, align: int = ALIGN, max_per_size: int = 32):
        self.align = align
        self.max_per_size = max_per_size
        self._lock = threading.Lock()
        self._free: Dict[int, List[np.ndarray]] = {}

    def acquire(self, nbytes: int) -> np.ndarray:
        size = align_up(max(nbytes, 1), self.align)
        with self._lock:
            bucket = self._free.get(size)
            if bucket:
                return bucket.pop()
        return aligned_empty(size, self.align)

    def release(self, buf: np.ndarray) -> None:
        with self._lock:
            bucket = self._free.setdefault(buf.nbytes, [])
            if len(bucket) < self.max_per_size:
                bucket.append(buf)
