"""Durable ``.npy`` file primitives for the checkpoint layer.

The checkpoint manager stages arrays as ``.npy`` shard files (chunk-CRC'd
by the manifest, see :mod:`repro.checkpoint.manager`).  The raw byte-level
operations behind that — binary ``open``, ``np.lib.format.open_memmap``,
``mmap_mode`` loads, fd fsync — live here so the rest of the tree stays on
the block API (the ``block-api-only`` pems-lint rule allowlists
``repro/io/`` precisely because this module is the audited home for them).
These helpers move *checkpoint* bytes, which are intentionally outside
:class:`~repro.core.iostats.IOLedger` accounting: the ledger models the
algorithm's I/O complexity, not snapshot traffic.
"""

from __future__ import annotations

import os

import numpy as np

__all__ = ["create_npy_memmap", "fsync_file", "load_npy_mmap",
           "save_npy_durable"]


def fsync_file(path: str) -> None:
    """fsync an existing file by path (e.g. after a memmap flush, whose
    ``msync`` alone does not guarantee metadata durability)."""
    with open(path, "rb+") as f:
        os.fsync(f.fileno())


def save_npy_durable(path: str, arr: np.ndarray) -> None:
    """``np.save`` + flush + fsync: the array is on stable storage when
    this returns (the caller owns any atomic-rename protocol above it)."""
    with open(path, "wb") as f:
        np.save(f, arr)
        f.flush()
        os.fsync(f.fileno())


def create_npy_memmap(path: str, dtype, shape) -> np.memmap:
    """A writable ``.npy``-format memmap at ``path`` (header included), for
    chunked out-of-core writes that never stage the full array in RAM."""
    return np.lib.format.open_memmap(path, mode="w+", dtype=dtype,
                                     shape=shape)


def load_npy_mmap(path: str) -> np.ndarray:
    """Read-only memmap view of a ``.npy`` file — the bounded-memory source
    for chunked restores."""
    return np.load(path, mmap_mode="r")
