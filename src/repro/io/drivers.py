"""File drivers for the :class:`~repro.io.engine.IOEngine`.

Three ways to move the same bytes, one positional-I/O interface
(``pread_into``/``pwrite``/``flush``/``close``, all thread-safe and
offset-explicit so concurrent workers never share a file position):

* :class:`BufferedFile` — ``os.preadv``/``os.pwritev`` through the kernel
  page cache.  The baseline: no alignment rules, but "disk" reads may be
  served from RAM, so cold-storage behaviour is unmeasurable.
* :class:`ODirectFile` — ``O_DIRECT``: transfers bypass the page cache and
  hit storage directly.  Offsets/lengths/buffers must be 4 KiB-aligned; the
  driver bounces unaligned requests through a reusable
  :class:`~repro.io.aligned.AlignedPool` buffer (read-modify-write for
  unaligned writes) and reports the *aligned* byte count as its syscall
  cost.  Where the filesystem rejects ``O_DIRECT`` (tmpfs, some network
  mounts) it falls back to buffered I/O with a warning and
  ``fallback=True`` — callers/CI can assert the documented fallback was
  taken instead of failing.
* :class:`MmapFile` — adapter over ``np.memmap`` so the historical memmap
  path runs through the exact same engine code as the other two drivers.

All drivers create-or-reuse their backing file: an existing file's contents
are preserved, and the file is only extended when it is smaller than the
requested size (never truncated — resuming from a populated backing file
must not zero it).
"""

from __future__ import annotations

import errno as _errno
import os
import warnings
from typing import Optional

import numpy as np

from .aligned import ALIGN, AlignedPool, align_down, align_up

IO_DRIVERS = ("buffered", "odirect", "mmap")


def _io_error(e: OSError, op: str, path, driver: str, offset: int,
              nbytes: int) -> OSError:
    """Re-raise helper: same errno, actionable message.

    A raw ``OSError`` surfacing from a worker thread names neither the file
    nor the request; this wraps it with op/offset/size/driver context (and a
    hint for ENOSPC) while keeping ``errno`` intact so the engine's
    transient/permanent classification still works.
    """
    code = _errno.errorcode.get(e.errno, str(e.errno))
    msg = (f"{op} of {nbytes:,} bytes at offset {offset:,} on {path!r} "
           f"({driver} driver) failed: [{code}] {e.strerror or e}")
    if e.errno == _errno.ENOSPC:
        msg += (" — the filesystem holding this backing file is out of "
                "space; free space or point backing_path at a larger volume")
    out = OSError(e.errno, msg)
    out.__cause__ = e
    return out


def ensure_file_size(path: str, size: int) -> None:
    """Create ``path`` or extend it to ``size`` bytes — never truncate.

    A caller-provided backing file holding real data (e.g. a resume after a
    checkpoint) keeps its contents; only missing bytes are added.
    """
    try:
        if not os.path.exists(path):
            with open(path, "wb") as f:
                f.truncate(size)
        elif os.path.getsize(path) < size:
            with open(path, "r+b") as f:
                f.truncate(size)
    except OSError as e:
        code = _errno.errorcode.get(e.errno, str(e.errno))
        msg = (f"cannot create/extend backing file {path!r} to {size:,} "
               f"bytes: [{code}] {e.strerror or e}")
        if e.errno == _errno.ENOSPC:
            msg += (" — the filesystem is out of space; free space or point "
                    "backing_path/the checkpoint dir at a larger volume")
        raise OSError(e.errno, msg) from e


class BufferedFile:
    """Positional buffered I/O (page-cached ``preadv``/``pwritev``)."""

    driver = "buffered"
    align = 1
    fallback = False

    def __init__(self, path: str, size: Optional[int] = None):
        self.path = path
        if size is not None:
            ensure_file_size(path, size)
        self.fd = os.open(path, os.O_RDWR | os.O_CREAT, 0o644)

    def pread_into(self, offset: int, out) -> int:
        """Fill the writable buffer ``out`` from ``offset``; returns the
        syscall-level byte count."""
        mv = memoryview(out).cast("B")
        try:
            return _buffered_pread(self.fd, mv, offset)
        except OSError as e:
            raise _io_error(e, "read", self.path, self.driver, offset,
                            len(mv))

    def pwrite(self, offset: int, data) -> int:
        mv = memoryview(np.ascontiguousarray(data)).cast("B")
        try:
            return _buffered_pwrite(self.fd, mv, offset)
        except OSError as e:
            raise _io_error(e, "write", self.path, self.driver, offset,
                            len(mv))

    def flush(self) -> None:
        os.fsync(self.fd)

    def close(self) -> None:
        if self.fd is not None:
            os.close(self.fd)
            self.fd = None


class ODirectFile:
    """``O_DIRECT`` positional I/O with an aligned bounce-buffer pool.

    Unaligned requests are widened to the enclosing 4 KiB block range;
    unaligned writes first read the boundary blocks (read-modify-write) so
    neighbouring bytes survive.  The engine serialises requests whose
    *aligned* block ranges overlap (see ``IOEngine``), which makes the RMW
    safe under concurrency.  ``pread_into``/``pwrite`` return the aligned
    byte count — the number the kernel actually transferred.
    """

    driver = "odirect"

    def __init__(self, path: str, size: Optional[int] = None):
        self.path = path
        if size is not None:
            # O_DIRECT transfers are whole blocks: keep the physical file an
            # exact multiple of the alignment so tail blocks stay in bounds.
            ensure_file_size(path, align_up(size, ALIGN))
        self.pool = AlignedPool(ALIGN)
        self.fallback = False
        self.align = ALIGN
        direct = getattr(os, "O_DIRECT", None)   # absent off-Linux
        if direct is None:
            self.fd = None
            self._fall_back(OSError("os.O_DIRECT not available on this "
                                    "platform"))
            return
        try:
            self.fd = os.open(path, os.O_RDWR | os.O_CREAT | direct, 0o644)
            # Some filesystems accept the flag at open() and fail at the
            # first transfer — probe with one aligned block read.
            probe = self.pool.acquire(ALIGN)
            try:
                os.preadv(self.fd, [probe], 0)
            finally:
                self.pool.release(probe)
        except OSError as e:
            self._fall_back(e)

    def _fall_back(self, err: OSError) -> None:
        warnings.warn(
            f"O_DIRECT unsupported on {self.path!r} ({err}); falling back "
            "to buffered I/O — cold-storage numbers will include the page "
            "cache",
            RuntimeWarning,
            stacklevel=3,
        )
        if getattr(self, "fd", None) is not None:
            try:
                os.close(self.fd)
            except OSError:
                pass
        self.fd = os.open(self.path, os.O_RDWR | os.O_CREAT, 0o644)
        self.fallback = True
        self.align = 1

    def pread_into(self, offset: int, out) -> int:
        mv = memoryview(out).cast("B")
        n = len(mv)
        try:
            if self.fallback:
                return _buffered_pread(self.fd, mv, offset)
            a0 = align_down(offset, ALIGN)
            a1 = align_up(offset + n, ALIGN)
            buf = self.pool.acquire(a1 - a0)
            try:
                got = os.preadv(self.fd, [buf[:a1 - a0]], a0)
                if got < a1 - a0:           # short read past the data tail
                    buf[got:a1 - a0] = 0
                mv[:] = buf[offset - a0:offset - a0 + n]
            finally:
                self.pool.release(buf)
            return a1 - a0
        except OSError as e:
            raise _io_error(e, "read", self.path, self.driver, offset, n)

    def pwrite(self, offset: int, data) -> int:
        src = memoryview(np.ascontiguousarray(data)).cast("B")
        n = len(src)
        try:
            if self.fallback:
                return _buffered_pwrite(self.fd, src, offset)
            a0 = align_down(offset, ALIGN)
            a1 = align_up(offset + n, ALIGN)
            buf = self.pool.acquire(a1 - a0)
            syscall = a1 - a0
            try:
                if a0 < offset:             # head block is partially ours
                    os.preadv(self.fd, [buf[:ALIGN]], a0)
                    syscall += ALIGN
                tail = a1 - ALIGN
                if (offset + n < a1
                        and tail >= a0 + (ALIGN if a0 < offset else 0)):
                    os.preadv(self.fd, [buf[tail - a0:a1 - a0]], tail)
                    syscall += ALIGN
                buf[offset - a0:offset - a0 + n] = src
                written = 0
                view = buf[:a1 - a0]
                while written < len(view):
                    written += os.pwritev(self.fd, [view[written:]],
                                          a0 + written)
            finally:
                self.pool.release(buf)
            return syscall
        except OSError as e:
            raise _io_error(e, "write", self.path, self.driver, offset, n)

    def flush(self) -> None:
        os.fsync(self.fd)

    def close(self) -> None:
        if self.fd is not None:
            os.close(self.fd)
            self.fd = None


class MmapFile:
    """``np.memmap`` adapter: the historical mmap tier behind the engine
    interface, so one submission/completion code path serves all drivers.

    Either wraps an existing 1-D uint8 memmap (``mm=``) or maps ``path``.
    """

    driver = "mmap"
    align = 1
    fallback = False

    def __init__(self, path: Optional[str] = None,
                 size: Optional[int] = None, mm: Optional[np.ndarray] = None):
        if mm is not None:
            self.path = getattr(mm, "filename", None)
            self.mm = mm
        else:
            ensure_file_size(path, size)
            self.path = path
            self.mm = np.memmap(path, dtype=np.uint8, mode="r+",
                                shape=(os.path.getsize(path),))

    def pread_into(self, offset: int, out) -> int:
        mv = np.frombuffer(memoryview(out).cast("B"), np.uint8)
        mv[:] = self.mm[offset:offset + mv.size]
        return mv.size

    def pwrite(self, offset: int, data) -> int:
        src = np.ascontiguousarray(data).reshape(-1).view(np.uint8)
        self.mm[offset:offset + src.size] = src
        return src.size

    def flush(self) -> None:
        if isinstance(self.mm, np.memmap):
            self.mm.flush()

    def close(self) -> None:
        self.flush()
        self.mm = None


def open_file(path: str, size: Optional[int], driver: str,
              fault_spec: Optional[str] = None):
    """Driver factory: ``buffered`` | ``odirect`` | ``mmap``, or any of
    them wrapped for fault injection as ``faulty:<inner>`` (the optional
    ``fault_spec`` string selects what to inject — see
    :mod:`repro.io.faults`) and/or for in-flight race detection as
    ``sanitize:<inner>`` (see :mod:`repro.io.sanitize`; wrappers compose,
    e.g. ``sanitize:faulty:buffered``)."""
    if driver.startswith("sanitize:"):
        from .sanitize import SanitizingFile
        inner = open_file(path, size, driver.split(":", 1)[1], fault_spec)
        return SanitizingFile(inner)
    if driver.startswith("faulty:"):
        from .faults import FaultSpec, FaultyFile
        inner = open_file(path, size, driver.split(":", 1)[1])
        return FaultyFile(inner, FaultSpec.parse(fault_spec))
    if fault_spec is not None:
        raise ValueError(
            f"fault_spec requires a 'faulty:<driver>' io driver, got "
            f"{driver!r}")
    if driver == "buffered":
        return BufferedFile(path, size)
    if driver == "odirect":
        return ODirectFile(path, size)
    if driver == "mmap":
        return MmapFile(path, size)
    raise ValueError(
        f"unknown io driver {driver!r} (choose from {IO_DRIVERS}, "
        "'faulty:<driver>', or 'sanitize:<driver>')")


def _buffered_pread(fd: int, mv: memoryview, offset: int) -> int:
    total = 0
    while total < len(mv):
        n = os.preadv(fd, [mv[total:]], offset + total)
        if n == 0:
            mv[total:] = bytes(len(mv) - total)
            break
        total += n
    return len(mv)


def _buffered_pwrite(fd: int, mv: memoryview, offset: int) -> int:
    total = 0
    while total < len(mv):
        total += os.pwritev(fd, [mv[total:]], offset + total)
    return total
