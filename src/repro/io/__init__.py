"""repro.io — asynchronous file I/O engine (PEMS2 §5.1 made real).

An io_uring-style submission/completion-queue engine
(:class:`~repro.io.engine.IOEngine`) over three positional-I/O drivers
(:mod:`repro.io.drivers`): page-cached ``buffered``, page-cache-bypassing
``odirect`` (4 KiB-aligned buffer pool, documented buffered fallback where
unsupported), and an ``mmap`` adapter wrapping the historical memmap path.
``repro.core.backing.FileBacking`` (``tier="file"``) and the checkpoint
manager stream through it; ``benchmarks/bench_io.py`` sweeps it.
"""

from .aligned import ALIGN, AlignedPool, aligned_empty, align_down, align_up
from .drivers import (
    BufferedFile,
    IO_DRIVERS,
    MmapFile,
    ODirectFile,
    ensure_file_size,
    open_file,
)
from .engine import IOEngine, IORequest

__all__ = [
    "ALIGN",
    "AlignedPool",
    "BufferedFile",
    "IOEngine",
    "IORequest",
    "IO_DRIVERS",
    "MmapFile",
    "ODirectFile",
    "aligned_empty",
    "align_down",
    "align_up",
    "ensure_file_size",
    "open_file",
]
