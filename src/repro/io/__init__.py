"""repro.io — asynchronous file I/O engine (PEMS2 §5.1 made real).

An io_uring-style submission/completion-queue engine
(:class:`~repro.io.engine.IOEngine`) over three positional-I/O drivers
(:mod:`repro.io.drivers`): page-cached ``buffered``, page-cache-bypassing
``odirect`` (4 KiB-aligned buffer pool, documented buffered fallback where
unsupported), and an ``mmap`` adapter wrapping the historical memmap path.
``repro.core.backing.FileBacking`` (``tier="file"``) and the checkpoint
manager stream through it; ``benchmarks/bench_io.py`` sweeps it.

Robustness layers on the same path: transient-error retries with bounded
exponential backoff in the engine, a deterministic fault-injecting driver
wrapper (:mod:`repro.io.faults`, ``io_driver="faulty:<inner>"``), and
per-block CRC sidecars (:mod:`repro.io.checksum`) that detect torn writes.
"""

from .aligned import ALIGN, AlignedPool, aligned_empty, align_down, align_up
from .checksum import (
    CHECK_BLOCK,
    CHECKSUM_ALGO,
    ChecksumSidecar,
    IntegrityError,
    crc_bytes,
)
from .drivers import (
    BufferedFile,
    IO_DRIVERS,
    MmapFile,
    ODirectFile,
    ensure_file_size,
    open_file,
)
from .engine import IOEngine, IORequest, TRANSIENT_ERRNOS
from .faults import FaultSpec, FaultyFile
from .npyio import (
    create_npy_memmap,
    fsync_file,
    load_npy_mmap,
    save_npy_durable,
)
from .sanitize import SanitizeFinding, SanitizingFile, collect_findings

__all__ = [
    "ALIGN",
    "AlignedPool",
    "BufferedFile",
    "CHECK_BLOCK",
    "CHECKSUM_ALGO",
    "ChecksumSidecar",
    "FaultSpec",
    "FaultyFile",
    "IntegrityError",
    "IOEngine",
    "IORequest",
    "IO_DRIVERS",
    "MmapFile",
    "ODirectFile",
    "SanitizeFinding",
    "SanitizingFile",
    "TRANSIENT_ERRNOS",
    "aligned_empty",
    "align_down",
    "align_up",
    "collect_findings",
    "crc_bytes",
    "create_npy_memmap",
    "ensure_file_size",
    "fsync_file",
    "load_npy_mmap",
    "open_file",
    "save_npy_durable",
]
