"""io_uring-style asynchronous file I/O engine.

The PEMS2 thesis' headline feature is asynchronous disk I/O that overlaps
swap traffic with compute (§5.1).  This engine makes that overlap real for
file-backed tiers: callers *submit* positional reads/writes into a bounded
queue and *poll*/*drain* completions, while a small worker pool executes the
transfers through one of the :mod:`repro.io.drivers` — so round ``r+1``'s
swap-in and round ``r-1``'s writeback are both in flight during round ``r``'s
compute, with measured queue-depth/stall/overlap counters instead of hope.

Semantics:

* ``submit_read(offset, out)`` / ``submit_write(offset, data)`` return an
  :class:`IORequest` immediately.  At most ``queue_depth`` requests are in
  flight; a submit into a full queue blocks (the measured
  ``queue_stall_s``) — backpressure, exactly like a full io_uring SQ.
* ``poll()`` returns (and forgets) completed requests without blocking.
* ``wait(reqs)`` blocks until the given requests complete; ``drain()``
  until *all* in-flight requests complete.  Both re-raise the first worker
  error.  After ``drain()``, ``in_flight == 0`` — guaranteed quiescence.
* For drivers with an alignment unit (``odirect``), requests whose aligned
  block ranges overlap are serialised when either is a write — the
  read-modify-write of boundary blocks would otherwise race.
* Transient errors (``EIO``/``EINTR``/``EAGAIN``/``ETIMEDOUT``) are retried
  in the worker up to ``retries`` times with exponential backoff and
  deterministic jitter before being treated as permanent; permanent errors
  (everything else, and exhausted retries) propagate per-request through
  ``wait``/``drain`` exactly as before.  ``retries``/``backoff_s``/
  ``permanent_errors`` counters record the policy's work.
* ``drain(timeout=)`` raises a :class:`TimeoutError` naming the still
  in-flight requests instead of deadlocking on a hung worker (a stalled
  disk, an injected latency fault).

The engine mirrors its measurements into the caller's
:class:`~repro.core.iostats.TierStats`-shaped object (``max_queue_depth``,
``queue_stall_s``, ``fsyncs``, ``rw_overlap_events``) and
:class:`~repro.core.iostats.IOLedger`-shaped object
(``syscall_read_bytes``/``syscall_write_bytes``); both are duck-typed so
this module stays import-independent of :mod:`repro.core`.
"""

from __future__ import annotations

import errno
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import List, Optional

from repro.obs import NOOP

from .aligned import align_down, align_up

_MAX_WORKERS = 16

# Errnos worth retrying: the device/kernel may succeed on a second attempt.
# Everything else (EINVAL, ENOSPC, EBADMSG/IntegrityError, ...) is permanent.
TRANSIENT_ERRNOS = frozenset(
    {errno.EIO, errno.EINTR, errno.EAGAIN, errno.ETIMEDOUT})


class IORequest:
    """One submitted transfer.  ``wait()`` blocks until completion and
    re-raises any worker error; ``done`` is non-blocking."""

    __slots__ = ("op", "offset", "nbytes", "data", "out", "syscall_bytes",
                 "error", "auto_reap", "attempts", "t_submit", "_a0", "_a1",
                 "_event")

    def __init__(self, op: str, offset: int, nbytes: int, data, out,
                 align: int, auto_reap: bool = False):
        self.op = op                    # "read" | "write"
        self.offset = offset
        self.nbytes = nbytes
        self.data = data                # write source (held until complete)
        self.out = out                  # read destination buffer
        self.t_submit = 0.0             # perf_counter at submit: request age
                                        # in drain diagnostics, queue time in
                                        # trace spans
        self.syscall_bytes = 0
        self.auto_reap = auto_reap      # fire-and-forget: skip _completed
        self.attempts = 0               # driver calls issued (1 = no retry)
        self.error: Optional[BaseException] = None
        self._a0 = align_down(offset, align) if align > 1 else offset
        self._a1 = (align_up(offset + nbytes, align) if align > 1
                    else offset + nbytes)
        self._event = threading.Event()

    @property
    def done(self) -> bool:
        return self._event.is_set()

    def wait(self) -> "IORequest":
        self._event.wait()
        if self.error is not None:
            raise self.error
        return self


class IOEngine:
    """Bounded submission/completion queues over one driver file.

    Parameters:

    * ``file`` — an open :mod:`repro.io.drivers` file object (``pread_into``/
      ``pwrite``/``flush``/``close`` plus an ``align`` unit in bytes).
    * ``queue_depth`` — maximum in-flight requests; a submit into a full
      queue blocks (measured as ``queue_stall_s``, seconds).
    * ``stats`` / ``ledger`` — duck-typed mirrors for the measured counters
      (see module docstring); byte counters are in bytes, ``*_s`` in seconds.
    * ``workers`` — worker-thread count (default ``min(queue_depth, 16)``).
    * ``retries`` — transient-error re-attempts per request (0 = fail fast).
    * ``backoff_s`` / ``backoff_max_s`` — base and cap of the exponential
      retry delay, in seconds.  ``jitter`` scales a deterministic per-attempt
      factor in ``[1, 1+jitter)``.
    * ``name`` — optional label (e.g. ``"shard1"`` under a sharded backing)
      included in drain-timeout diagnostics so a hung shard is identifiable.
    """

    def __init__(self, file, queue_depth: int = 8, stats=None, ledger=None,
                 workers: Optional[int] = None, retries: int = 2,
                 backoff_s: float = 0.002, backoff_max_s: float = 0.25,
                 jitter: float = 0.25, name: Optional[str] = None):
        if queue_depth < 1:
            raise ValueError(f"queue_depth must be >= 1, got {queue_depth}")
        if retries < 0:
            raise ValueError(f"retries must be >= 0, got {retries}")
        self.file = file
        self.queue_depth = queue_depth
        self.stats = stats
        self.ledger = ledger
        self.name = name
        # Retry policy for transient errors (see TRANSIENT_ERRNOS): up to
        # ``retries`` re-attempts, delay min(backoff_max_s, backoff_s·2^i)
        # scaled by a deterministic per-(request, attempt) jitter factor so
        # schedules are reproducible yet colliding retries still spread out.
        self.max_retries = retries
        self._backoff_base_s = backoff_s
        self._backoff_cap_s = backoff_max_s
        self._jitter = jitter
        self._slots = threading.Semaphore(queue_depth)
        self._lock = threading.Lock()
        self._stats_lock = threading.Lock()   # guards _bump only; may be
                                              # taken while holding _lock
        self._quiet = threading.Condition(self._lock)
        self._inflight: List[IORequest] = []
        self._completed: List[IORequest] = []
        self._reads = 0
        self._writes = 0
        self._closed = False
        # Local mirrors of the duck-typed stats (always available, e.g. for
        # a standalone engine in benchmarks/tests).
        self.max_queue_depth = 0
        self.queue_stall_s = 0.0
        self.fsyncs = 0
        self.rw_overlap_events = 0
        self.syscall_read_bytes = 0
        self.syscall_write_bytes = 0
        self.retries = 0                # transient re-attempts issued
        self.backoff_s = 0.0            # scheduled backoff (deterministic)
        self.permanent_errors = 0       # requests that finally errored
        # repro.obs tracing: attached post-construction by the executor
        # (like the duck-typed stats/ledger mirrors).  NOOP by default, so
        # the per-request instrumentation costs one attribute check.
        self.tracer = NOOP
        # Test hook: workers block here before touching the file, so tests
        # can hold requests in flight deterministically.  Set by default.
        self._gate = threading.Event()
        self._gate.set()
        self._pool = ThreadPoolExecutor(
            max_workers=workers or min(queue_depth, _MAX_WORKERS),
            thread_name_prefix="repro-io",
        )

    # ------------------------------------------------------------- submission
    def submit_read(self, offset: int, out,
                    auto_reap: bool = False) -> IORequest:
        """Read ``len(out)`` bytes at ``offset`` into the writable buffer
        ``out`` (filled by completion time)."""
        req = IORequest("read", offset, memoryview(out).cast("B").nbytes,
                        None, out, self.file.align, auto_reap)
        return self._submit(req)

    def submit_write(self, offset: int, data,
                     auto_reap: bool = False) -> IORequest:
        """Write the buffer ``data`` at ``offset``.  The engine holds a
        reference until completion — callers may drop theirs immediately.
        ``auto_reap=True`` marks a fire-and-forget request: a successful
        completion is dropped instead of queued for ``poll`` (errors are
        still kept for ``drain``), so an unbounded stream of async
        writebacks does not grow the completion list."""
        req = IORequest("write", offset, memoryview(data).cast("B").nbytes,
                        data, None, self.file.align, auto_reap)
        return self._submit(req)

    def _submit(self, req: IORequest) -> IORequest:
        if self._closed:
            raise RuntimeError("submit on a closed IOEngine")
        req.t_submit = time.perf_counter()
        if not self._slots.acquire(blocking=False):
            t0 = time.perf_counter()
            self._slots.acquire()
            self._bump("queue_stall_s", time.perf_counter() - t0)
        with self._lock:
            if ((req.op == "read" and self._writes > 0)
                    or (req.op == "write" and self._reads > 0)):
                self._bump("rw_overlap_events", 1)
            if self.file.align > 1:
                # Serialise aligned-range conflicts: an O_DIRECT boundary
                # block is read-modify-written, so two requests touching the
                # same block (either being a write) must not interleave.
                while self._conflicts(req):
                    self._quiet.wait()
            self._inflight.append(req)
            # Sanitizer hook (duck-typed, e.g. io.sanitize.SanitizingFile):
            # fires once the request joins the in-flight set, after any
            # aligned-conflict serialisation above — so ranges the engine
            # serialises never co-exist in the sanitizer's view either.
            note = getattr(self.file, "note_submit", None)
            if note is not None:
                note(req)
            if req.op == "read":
                self._reads += 1
            else:
                self._writes += 1
            depth = len(self._inflight)
            self.max_queue_depth = max(self.max_queue_depth, depth)
            if self.stats is not None:
                self.stats.max_queue_depth = max(
                    self.stats.max_queue_depth, depth)
        if self.tracer.enabled:
            self.tracer.counter("queue_depth", depth, tid="queue")
        self._pool.submit(self._execute, req)
        return req

    def _conflicts(self, req: IORequest) -> bool:
        for r in self._inflight:
            if (r._a0 < req._a1 and req._a0 < r._a1
                    and ("write" in (r.op, req.op))):
                return True
        return False

    # -------------------------------------------------------------- execution
    def _backoff_delay(self, req: IORequest, attempt: int) -> float:
        d = min(self._backoff_cap_s, self._backoff_base_s * (2 ** attempt))
        if self._jitter:
            # Deterministic jitter in [1, 1+jitter): a hash of the request's
            # identity and the attempt number, not a PRNG — retry schedules
            # are exactly reproducible for tests and postmortems.
            h = (req.offset * 1000003 + attempt * 8191 + req.nbytes)
            h = (h * 2654435761) & 0xFFFFFFFF
            d *= 1.0 + self._jitter * (h / 2.0 ** 32)
        return d

    def _execute(self, req: IORequest) -> None:
        self._gate.wait()
        t_exec0 = time.perf_counter()
        attempt = 0
        while True:
            try:
                if req.op == "read":
                    n = self.file.pread_into(req.offset, req.out)
                else:
                    n = self.file.pwrite(req.offset, req.data)
                req.syscall_bytes = n
                req.attempts = attempt + 1
                break
            except BaseException as e:   # propagate through wait()/drain()
                if (isinstance(e, OSError)
                        and e.errno in TRANSIENT_ERRNOS
                        and attempt < self.max_retries):
                    delay = self._backoff_delay(req, attempt)
                    self._bump("retries", 1)
                    self._bump("backoff_s", delay)
                    attempt += 1
                    if delay > 0:
                        time.sleep(delay)
                    continue
                req.error = e
                req.attempts = attempt + 1
                self._bump("permanent_errors", 1)
                break
        # Sanitizer hook: the write buffer is still held here, so its
        # submit-time CRC can be checked against what the worker saw.
        note = getattr(self.file, "note_complete", None)
        if note is not None:
            note(req)
        with self._lock:
            self._inflight.remove(req)
            if req.op == "read":
                self._reads -= 1
                if req.error is None:
                    self.syscall_read_bytes += req.syscall_bytes
                    if self.ledger is not None:
                        self.ledger.syscall_read_bytes += req.syscall_bytes
            else:
                self._writes -= 1
                if req.error is None:
                    self.syscall_write_bytes += req.syscall_bytes
                    if self.ledger is not None:
                        self.ledger.syscall_write_bytes += req.syscall_bytes
            req.data = None          # free the held write buffer …
            req.out = None           # … and the read destination reference
            if not req.auto_reap or req.error is not None:
                self._completed.append(req)
            depth = len(self._inflight)
            self._quiet.notify_all()
        if self.tracer.enabled:
            # One complete span per request on this worker thread's lane:
            # the driver execution (incl. retries/backoff), with queue time
            # as an attribute — submit→execute→complete in one event.
            self.tracer.complete(
                req.op, t_exec0, time.perf_counter(),
                tid=threading.current_thread().name, cat="request",
                offset=req.offset, bytes=req.nbytes,
                driver=getattr(self.file, "driver", "?"),
                retries=req.attempts - 1,
                queued_us=round((t_exec0 - req.t_submit) * 1e6),
                error=type(req.error).__name__ if req.error else None)
            self.tracer.counter("queue_depth", depth, tid="queue")
        req._event.set()
        self._slots.release()

    # ------------------------------------------------------------- completion
    def poll(self) -> List[IORequest]:
        """Completed-so-far requests (each reaped exactly once, like CQEs).
        A polled request's error is the caller's to inspect — ``drain()``
        only re-raises errors of requests nobody has reaped yet."""
        with self._lock:
            done, self._completed = self._completed, []
        return done

    def wait(self, reqs) -> None:
        """Block until every request in ``reqs`` completes; raise the first
        error.  Reaps the waited requests (their errors are this caller's,
        and the completion list must not grow with every wait-style batch),
        so a later ``poll``/``drain`` no longer sees them."""
        reqs = list(reqs)
        err = None
        for r in reqs:
            r._event.wait()
            if err is None and r.error is not None:
                err = r.error
        with self._lock:
            waited = set(reqs)
            self._completed = [c for c in self._completed
                               if c not in waited]
        if err is not None:
            raise err

    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until no request is in flight.  On return,
        ``in_flight == 0`` and every error raised.

        With ``timeout`` (seconds), a hung worker raises a diagnostic
        :class:`TimeoutError` naming the stuck requests instead of
        deadlocking the caller; the requests stay in flight (a later
        ``drain()`` can still collect them if the worker recovers).
        """
        deadline = (time.monotonic() + timeout) if timeout is not None \
            else None
        with self._quiet:
            while self._inflight:
                if deadline is None:
                    self._quiet.wait()
                    continue
                left = deadline - time.monotonic()
                if left <= 0:
                    # Each stuck request's age (since submit) and byte
                    # range: enough to tell a wedged worker from a slow
                    # one, and to map the range back to context rows.
                    now = time.perf_counter()
                    pend = [
                        (r.op, f"[{r.offset},{r.offset + r.nbytes})",
                         f"age={now - r.t_submit:.3f}s")
                        for r in self._inflight
                    ]
                    who = f"engine {self.name!r} " if self.name else ""
                    self.tracer.instant(
                        "drain_timeout", tid="events", cat="engine",
                        timeout_s=timeout, in_flight=len(pend),
                        stuck=[list(p) for p in pend[:4]])
                    raise TimeoutError(
                        f"IOEngine.drain timed out after {timeout}s with "
                        f"{len(pend)} request(s) still in flight on "
                        f"{who}{getattr(self.file, 'path', '?')!r} (driver="
                        f"{getattr(self.file, 'driver', '?')}): first "
                        f"{pend[:4]} as (op, [byte range), age since "
                        "submit) — a worker is stuck; check for a stalled "
                        "device, an injected latency fault, or a held "
                        "test gate")
                self._quiet.wait(left)
            done, self._completed = self._completed, []
        for r in done:
            if r.error is not None:
                raise r.error

    @property
    def in_flight(self) -> int:
        with self._lock:
            return len(self._inflight)

    # ------------------------------------------------------------- durability
    def fsync(self) -> None:
        """Drain, then push everything to stable storage."""
        self.drain()
        self.file.flush()
        self._bump("fsyncs", 1)

    def close(self) -> None:
        if self._closed:
            return
        self.drain()
        self._closed = True
        self._pool.shutdown(wait=True)
        self.file.close()

    # ---------------------------------------------------------------- helpers
    def _bump(self, name: str, val) -> None:
        # Concurrent submitters (main writeback + prefetch reads) can stall
        # simultaneously; the read-modify-write must not lose increments.
        with self._stats_lock:
            setattr(self, name, getattr(self, name) + val)
            if self.stats is not None:
                setattr(self.stats, name, getattr(self.stats, name) + val)
