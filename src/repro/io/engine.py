"""io_uring-style asynchronous file I/O engine.

The PEMS2 thesis' headline feature is asynchronous disk I/O that overlaps
swap traffic with compute (§5.1).  This engine makes that overlap real for
file-backed tiers: callers *submit* positional reads/writes into a bounded
queue and *poll*/*drain* completions, while a small worker pool executes the
transfers through one of the :mod:`repro.io.drivers` — so round ``r+1``'s
swap-in and round ``r-1``'s writeback are both in flight during round ``r``'s
compute, with measured queue-depth/stall/overlap counters instead of hope.

Semantics:

* ``submit_read(offset, out)`` / ``submit_write(offset, data)`` return an
  :class:`IORequest` immediately.  At most ``queue_depth`` requests are in
  flight; a submit into a full queue blocks (the measured
  ``queue_stall_s``) — backpressure, exactly like a full io_uring SQ.
* ``poll()`` returns (and forgets) completed requests without blocking.
* ``wait(reqs)`` blocks until the given requests complete; ``drain()``
  until *all* in-flight requests complete.  Both re-raise the first worker
  error.  After ``drain()``, ``in_flight == 0`` — guaranteed quiescence.
* For drivers with an alignment unit (``odirect``), requests whose aligned
  block ranges overlap are serialised when either is a write — the
  read-modify-write of boundary blocks would otherwise race.

The engine mirrors its measurements into the caller's
:class:`~repro.core.iostats.TierStats`-shaped object (``max_queue_depth``,
``queue_stall_s``, ``fsyncs``, ``rw_overlap_events``) and
:class:`~repro.core.iostats.IOLedger`-shaped object
(``syscall_read_bytes``/``syscall_write_bytes``); both are duck-typed so
this module stays import-independent of :mod:`repro.core`.
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import List, Optional

from .aligned import align_down, align_up

_MAX_WORKERS = 16


class IORequest:
    """One submitted transfer.  ``wait()`` blocks until completion and
    re-raises any worker error; ``done`` is non-blocking."""

    __slots__ = ("op", "offset", "nbytes", "data", "out", "syscall_bytes",
                 "error", "auto_reap", "_a0", "_a1", "_event")

    def __init__(self, op: str, offset: int, nbytes: int, data, out,
                 align: int, auto_reap: bool = False):
        self.op = op                    # "read" | "write"
        self.offset = offset
        self.nbytes = nbytes
        self.data = data                # write source (held until complete)
        self.out = out                  # read destination buffer
        self.syscall_bytes = 0
        self.auto_reap = auto_reap      # fire-and-forget: skip _completed
        self.error: Optional[BaseException] = None
        self._a0 = align_down(offset, align) if align > 1 else offset
        self._a1 = (align_up(offset + nbytes, align) if align > 1
                    else offset + nbytes)
        self._event = threading.Event()

    @property
    def done(self) -> bool:
        return self._event.is_set()

    def wait(self) -> "IORequest":
        self._event.wait()
        if self.error is not None:
            raise self.error
        return self


class IOEngine:
    """Bounded submission/completion queues over one driver file."""

    def __init__(self, file, queue_depth: int = 8, stats=None, ledger=None,
                 workers: Optional[int] = None):
        if queue_depth < 1:
            raise ValueError(f"queue_depth must be >= 1, got {queue_depth}")
        self.file = file
        self.queue_depth = queue_depth
        self.stats = stats
        self.ledger = ledger
        self._slots = threading.Semaphore(queue_depth)
        self._lock = threading.Lock()
        self._stats_lock = threading.Lock()   # guards _bump only; may be
                                              # taken while holding _lock
        self._quiet = threading.Condition(self._lock)
        self._inflight: List[IORequest] = []
        self._completed: List[IORequest] = []
        self._reads = 0
        self._writes = 0
        self._closed = False
        # Local mirrors of the duck-typed stats (always available, e.g. for
        # a standalone engine in benchmarks/tests).
        self.max_queue_depth = 0
        self.queue_stall_s = 0.0
        self.fsyncs = 0
        self.rw_overlap_events = 0
        self.syscall_read_bytes = 0
        self.syscall_write_bytes = 0
        # Test hook: workers block here before touching the file, so tests
        # can hold requests in flight deterministically.  Set by default.
        self._gate = threading.Event()
        self._gate.set()
        self._pool = ThreadPoolExecutor(
            max_workers=workers or min(queue_depth, _MAX_WORKERS),
            thread_name_prefix="repro-io",
        )

    # ------------------------------------------------------------- submission
    def submit_read(self, offset: int, out,
                    auto_reap: bool = False) -> IORequest:
        """Read ``len(out)`` bytes at ``offset`` into the writable buffer
        ``out`` (filled by completion time)."""
        req = IORequest("read", offset, memoryview(out).cast("B").nbytes,
                        None, out, self.file.align, auto_reap)
        return self._submit(req)

    def submit_write(self, offset: int, data,
                     auto_reap: bool = False) -> IORequest:
        """Write the buffer ``data`` at ``offset``.  The engine holds a
        reference until completion — callers may drop theirs immediately.
        ``auto_reap=True`` marks a fire-and-forget request: a successful
        completion is dropped instead of queued for ``poll`` (errors are
        still kept for ``drain``), so an unbounded stream of async
        writebacks does not grow the completion list."""
        req = IORequest("write", offset, memoryview(data).cast("B").nbytes,
                        data, None, self.file.align, auto_reap)
        return self._submit(req)

    def _submit(self, req: IORequest) -> IORequest:
        if self._closed:
            raise RuntimeError("submit on a closed IOEngine")
        if not self._slots.acquire(blocking=False):
            t0 = time.perf_counter()
            self._slots.acquire()
            self._bump("queue_stall_s", time.perf_counter() - t0)
        with self._lock:
            if ((req.op == "read" and self._writes > 0)
                    or (req.op == "write" and self._reads > 0)):
                self._bump("rw_overlap_events", 1)
            if self.file.align > 1:
                # Serialise aligned-range conflicts: an O_DIRECT boundary
                # block is read-modify-written, so two requests touching the
                # same block (either being a write) must not interleave.
                while self._conflicts(req):
                    self._quiet.wait()
            self._inflight.append(req)
            if req.op == "read":
                self._reads += 1
            else:
                self._writes += 1
            depth = len(self._inflight)
            self.max_queue_depth = max(self.max_queue_depth, depth)
            if self.stats is not None:
                self.stats.max_queue_depth = max(
                    self.stats.max_queue_depth, depth)
        self._pool.submit(self._execute, req)
        return req

    def _conflicts(self, req: IORequest) -> bool:
        for r in self._inflight:
            if (r._a0 < req._a1 and req._a0 < r._a1
                    and ("write" in (r.op, req.op))):
                return True
        return False

    # -------------------------------------------------------------- execution
    def _execute(self, req: IORequest) -> None:
        self._gate.wait()
        try:
            if req.op == "read":
                n = self.file.pread_into(req.offset, req.out)
            else:
                n = self.file.pwrite(req.offset, req.data)
            req.syscall_bytes = n
        except BaseException as e:   # propagate through wait()/drain()
            req.error = e
        with self._lock:
            self._inflight.remove(req)
            if req.op == "read":
                self._reads -= 1
                if req.error is None:
                    self.syscall_read_bytes += req.syscall_bytes
                    if self.ledger is not None:
                        self.ledger.syscall_read_bytes += req.syscall_bytes
            else:
                self._writes -= 1
                if req.error is None:
                    self.syscall_write_bytes += req.syscall_bytes
                    if self.ledger is not None:
                        self.ledger.syscall_write_bytes += req.syscall_bytes
            req.data = None          # free the held write buffer …
            req.out = None           # … and the read destination reference
            if not req.auto_reap or req.error is not None:
                self._completed.append(req)
            self._quiet.notify_all()
        req._event.set()
        self._slots.release()

    # ------------------------------------------------------------- completion
    def poll(self) -> List[IORequest]:
        """Completed-so-far requests (each reaped exactly once, like CQEs).
        A polled request's error is the caller's to inspect — ``drain()``
        only re-raises errors of requests nobody has reaped yet."""
        with self._lock:
            done, self._completed = self._completed, []
        return done

    def wait(self, reqs) -> None:
        """Block until every request in ``reqs`` completes; raise the first
        error.  Reaps the waited requests (their errors are this caller's,
        and the completion list must not grow with every wait-style batch),
        so a later ``poll``/``drain`` no longer sees them."""
        reqs = list(reqs)
        err = None
        for r in reqs:
            r._event.wait()
            if err is None and r.error is not None:
                err = r.error
        with self._lock:
            waited = set(reqs)
            self._completed = [c for c in self._completed
                               if c not in waited]
        if err is not None:
            raise err

    def drain(self) -> None:
        """Block until no request is in flight.  On return,
        ``in_flight == 0`` and every error raised."""
        with self._quiet:
            while self._inflight:
                self._quiet.wait()
            done, self._completed = self._completed, []
        for r in done:
            if r.error is not None:
                raise r.error

    @property
    def in_flight(self) -> int:
        with self._lock:
            return len(self._inflight)

    # ------------------------------------------------------------- durability
    def fsync(self) -> None:
        """Drain, then push everything to stable storage."""
        self.drain()
        self.file.flush()
        self._bump("fsyncs", 1)

    def close(self) -> None:
        if self._closed:
            return
        self.drain()
        self._closed = True
        self._pool.shutdown(wait=True)
        self.file.close()

    # ---------------------------------------------------------------- helpers
    def _bump(self, name: str, val) -> None:
        # Concurrent submitters (main writeback + prefetch reads) can stall
        # simultaneously; the read-modify-write must not lose increments.
        with self._stats_lock:
            setattr(self, name, getattr(self, name) + val)
            if self.stats is not None:
                setattr(self.stats, name, getattr(self.stats, name) + val)
