"""Per-block CRC sidecars: torn-write *detection* for the disk backings.

A crash (or an injected fault) can leave a block half-new, half-old — a torn
write.  Without integrity metadata the next read silently merges the two
generations and the corruption propagates into results.  This module stores
one CRC per ``CHECK_BLOCK``-byte segment of every context row in a sidecar
file next to the backing file (``<path>.crc``), so a torn write is *detected*
at the first read instead of silently merged:

* segments are **within-row**: the grid restarts at every row start, so two
  rows never share a checksum block.  Rounds and collectives touch disjoint
  row ranges, which makes concurrent checksummed writes race-free without any
  extra locking (the same invariant ``FileBacking`` already relies on).
* a write covering a segment completely recomputes its CRC from the new bytes
  alone (the backing-tier hot path — whole-row swaps — never reads back);
  a write covering a segment *partially* read-modify-writes that segment,
  verifying the pre-image first so a torn block is never blessed into a new
  checksum.
* CRCs are recorded at submission time (the *intended* contents), so a write
  that dies midway leaves a mismatch behind by construction.

The checksum is CRC32C (Castagnoli) when the ``crc32c`` module is available
(hardware-accelerated on SSE4.2/NEON), else the stdlib ``zlib.adler32`` —
roughly 4× faster than ``zlib.crc32`` and, over ``CHECK_BLOCK``-sized
segments, equally certain to catch a torn write (a zeroed or stale tail);
Adler-32's known weakness is only on very short messages.  The sidecar
header records which algorithm wrote it, and a sidecar written with an
unavailable algorithm is refused rather than mis-verified.
"""

from __future__ import annotations

import errno
import os
from typing import List, Optional, Sequence, Tuple

import numpy as np

try:                                    # SSE4.2/NEON Castagnoli when present
    from crc32c import crc32c as _crc

    CHECKSUM_ALGO = "crc32c"
    _ALGO_ID = 1
except ImportError:                     # fastest stdlib checksum
    from zlib import adler32 as _crc

    CHECKSUM_ALGO = "adler32"
    _ALGO_ID = 2

_ALGO_NAMES = {0: "crc32", 1: "crc32c", 2: "adler32"}

# Checksum granularity.  64 KiB keeps the steady-state cost low (fewer,
# larger hash calls; less per-segment Python) while still detecting any
# torn write — tearing happens at sector/page grain, far below this.
CHECK_BLOCK = 64 * 1024

_MAGIC = b"PEMSCRC2"
_HEADER = 64                            # fixed header size, entries follow


class IntegrityError(OSError):
    """Checksummed bytes do not match their recorded CRC.

    Raised on read (or on the pre-image verify of a partial-segment write)
    when the stored CRC disagrees with the bytes on disk — a torn write,
    bit rot, or an out-of-band mutation of the backing file.  Carries
    ``path``/``row``/``seg`` so the failing block is actionable.  The errno
    is ``EBADMSG``: *not* a transient error, the engine never retries it.
    """

    def __init__(self, msg: str, *, path: Optional[str] = None,
                 row: Optional[int] = None, seg: Optional[int] = None):
        super().__init__(errno.EBADMSG, msg)
        self.path = path
        self.row = row
        self.seg = seg


def crc_bytes(buf) -> int:
    """CRC of a bytes-like/contiguous-ndarray buffer (uint32)."""
    return _crc(buf) & 0xFFFFFFFF


# --------------------------------------------------------------------------- #
# Segment geometry helpers (shared by FileBacking / MemmapBacking)             #
# --------------------------------------------------------------------------- #

def seg_range(b0: int, nb: int, chk: int = CHECK_BLOCK) -> Tuple[int, int]:
    """Inclusive segment index range [s0, s1] covering bytes [b0, b0+nb)."""
    return b0 // chk, (b0 + nb - 1) // chk


def span_plan(byte_ranges: Sequence[Tuple[int, int]], chk: int,
              rowbytes: int) -> List[Tuple[int, int, List[int]]]:
    """Plan the segment work for a set of disjoint within-row byte ranges.

    Returns ``[(s0, s1, partial_segs)]`` — maximal runs of *consecutive*
    touched segments, with the sub-list of segments only partially covered
    by the ranges (those need a verified pre-image before their CRC can be
    recomputed; fully-covered segments are rebuilt from the new bytes alone).
    """
    if not byte_ranges:
        return []
    ranges = sorted(byte_ranges)
    touched: List[int] = []
    for b0, b1 in ranges:
        s0, s1 = seg_range(b0, b1 - b0, chk)
        if touched and s0 <= touched[-1]:
            s0 = touched[-1] + 1
        touched.extend(range(s0, s1 + 1))

    def covered(seg: int) -> bool:
        g0, g1 = seg * chk, min(rowbytes, (seg + 1) * chk)
        pos = g0
        for b0, b1 in ranges:
            if b1 <= pos:
                continue
            if b0 > pos:
                return False
            pos = b1
            if pos >= g1:
                return True
        return pos >= g1

    spans: List[Tuple[int, int, List[int]]] = []
    for s in touched:
        if spans and s == spans[-1][1] + 1:
            s0, _, partial = spans[-1]
            spans[-1] = (s0, s, partial)
        else:
            spans.append((s, s, []))
        if not covered(s):
            spans[-1][2].append(s)
    return spans


# --------------------------------------------------------------------------- #
# The sidecar                                                                  #
# --------------------------------------------------------------------------- #

class ChecksumSidecar:
    """``<data path>.crc``: one uint32 CRC per ``chk``-byte segment per row.

    Create-or-reuse like the backing files themselves: an existing sidecar
    whose header matches (magic, algorithm, ``v``, ``rowbytes``, ``chk``) is
    reopened; anything else is recreated and ``fresh`` is set so the owner
    can seed it (zero-fill for a new backing file, or a full recompute for
    an adopted one).
    """

    def __init__(self, data_path: str, v: int, rowbytes: int,
                 chk: int = CHECK_BLOCK):
        self.data_path = data_path
        self.path = data_path + ".crc"
        self.v = v
        self.rowbytes = rowbytes
        self.chk = chk
        self.nseg = -(-rowbytes // chk)
        self.fresh = not self._reusable()
        if self.fresh:
            self._create()
        self.crcs = np.memmap(self.path, dtype=np.uint32, mode="r+",
                              offset=_HEADER, shape=(v, self.nseg))

    # ------------------------------------------------------------- lifecycle
    def _header(self) -> bytes:
        h = np.zeros(_HEADER, np.uint8)
        h[:8] = np.frombuffer(_MAGIC, np.uint8)
        np.frombuffer(h, np.uint32, 3, 8)[:] = (1, _ALGO_ID, self.chk)
        np.frombuffer(h, np.uint64, 2, 24)[:] = (self.v, self.rowbytes)
        return h.tobytes()

    def _reusable(self) -> bool:
        try:
            with open(self.path, "rb") as f:
                head = f.read(_HEADER)
            size_ok = (os.path.getsize(self.path)
                       == _HEADER + 4 * self.v * self.nseg)
        except OSError:
            return False
        if len(head) != _HEADER or head[:8] != _MAGIC:
            return False
        _ver, algo, chk = np.frombuffer(head, np.uint32, 3, 8)
        v, rowbytes = np.frombuffer(head, np.uint64, 2, 24)
        if (int(v), int(rowbytes), int(chk)) != (self.v, self.rowbytes,
                                                 self.chk) or not size_ok:
            return False
        if int(algo) != _ALGO_ID:
            name = _ALGO_NAMES.get(int(algo), f"algorithm #{int(algo)}")
            raise IntegrityError(
                f"checksum sidecar {self.path!r} was written with "
                f"{name} but this interpreter "
                f"only has {CHECKSUM_ALGO}; install the matching module or "
                "delete the sidecar to recompute",
                path=self.path,
            )
        return True

    def _create(self) -> None:
        with open(self.path, "wb") as f:
            f.write(self._header())
            f.truncate(_HEADER + 4 * self.v * self.nseg)

    def seed_zero(self) -> None:
        """Seed every entry with the CRC of an all-zero segment (a freshly
        created, hole-punched backing file reads as zeros)."""
        z = np.zeros(self.chk, np.uint8)
        full = crc_bytes(z)
        tail_len = self.rowbytes - (self.nseg - 1) * self.chk
        tail = crc_bytes(z[:tail_len]) if tail_len != self.chk else full
        self.crcs[:, :] = full
        self.crcs[:, -1] = tail
        self.fresh = False

    def flush(self) -> None:
        self.crcs.flush()

    # ------------------------------------------------------------ seg bounds
    def seg_bounds(self, s: int) -> Tuple[int, int]:
        b0 = s * self.chk
        return b0, min(self.rowbytes, b0 + self.chk)

    # ----------------------------------------------------------- row updates
    def set_rows(self, r0: int, rows_u8: np.ndarray) -> None:
        """Record the CRCs of full rows ``[r0, r0+len)`` from their bytes
        (``rows_u8``: ``[rows, rowbytes]`` uint8)."""
        for i in range(rows_u8.shape[0]):
            self.set_span(r0 + i, 0, rows_u8[i])

    def verify_rows(self, r0: int, rows_u8: np.ndarray) -> None:
        for i in range(rows_u8.shape[0]):
            self.verify_span(r0 + i, 0, rows_u8[i])

    def set_span(self, row: int, s0: int, buf: np.ndarray) -> None:
        """Record CRCs for the consecutive segments starting at ``s0`` whose
        bytes are ``buf`` (which starts exactly at ``s0``'s boundary)."""
        s, off, n = s0, 0, len(buf)
        while off < n:
            b0, b1 = self.seg_bounds(s)
            ln = b1 - b0
            self.crcs[row, s] = crc_bytes(buf[off:off + ln])
            s += 1
            off += ln

    def verify_span(self, row: int, s0: int, buf: np.ndarray) -> None:
        s, off, n = s0, 0, len(buf)
        while off < n:
            b0, b1 = self.seg_bounds(s)
            ln = b1 - b0
            got = crc_bytes(buf[off:off + ln])
            want = int(self.crcs[row, s])
            if got != want:
                raise IntegrityError(
                    f"checksum mismatch on {self.data_path!r}: row {row}, "
                    f"segment {s} (bytes [{row * self.rowbytes + b0:,}, "
                    f"{row * self.rowbytes + b1:,}) of the file): stored "
                    f"{CHECKSUM_ALGO}=0x{want:08x}, data reads 0x{got:08x} "
                    "— a torn write, bit rot, or an out-of-band mutation; "
                    "restore from the last checkpoint/superstep cursor "
                    "instead of trusting these bytes",
                    path=self.data_path, row=row, seg=s,
                )
            s += 1
            off += ln
