from .sharding import batch_specs_sharded, cache_pspec, param_pspecs, ShardingRules

__all__ = ["ShardingRules", "batch_specs_sharded", "cache_pspec", "param_pspecs"]
