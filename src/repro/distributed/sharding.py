"""Sharding rules: map every parameter / activation / cache tensor to a
PartitionSpec on the (pod, data, model) production mesh.

Strategy (GSPMD fills in the collectives):

* **TP** on the model axis: attention heads, FFN hidden dim, expert dim,
  vocab dim.
* **DP** on (pod, data): the batch dimension of activations and caches.
* **FSDP** (optional) on the data axis: parameters additionally sharded on a
  non-TP dim so the giant MoE configs fit (ZeRO-3 style; GSPMD all-gathers
  them per layer inside the scan).
* A dim is only assigned a mesh axis when divisible by it — otherwise the
  tensor is replicated on that axis (e.g. kv_heads=1 MQA replicates KV).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    model_axis: str = "model"
    data_axes: Tuple[str, ...] = ("data",)     # ("pod", "data") multi-pod
    fsdp: bool = False                         # shard params on data too
    # Which expert-weight dim carries the FSDP shard:
    #   "ff"       — output/hidden dim (ZeRO-style; XLA hoists the gather of
    #                the whole stacked expert array out of the layer scan)
    #   "contract" — contraction dim (matmul partial-sums + psum; weights are
    #                never gathered)  [§Perf iteration #4]
    expert_fsdp_dim: str = "contract"

    @property
    def model_size(self) -> int:
        return self.mesh.shape[self.model_axis]

    @property
    def data_size(self) -> int:
        out = 1
        for a in self.data_axes:
            out *= self.mesh.shape[a]
        return out


def _divisible(dim: int, size: int) -> bool:
    return dim % size == 0 and dim >= size


def _spec_for_param(rules: ShardingRules, path: str,
                    shape: Tuple[int, ...]) -> P:
    """Parameter placement by name pattern.  Leading 'layers' stack dims are
    never sharded."""
    ax_m = rules.model_axis
    ms = rules.model_size
    spec = [None] * len(shape)

    def put(dim: int, axis) -> bool:
        size = (rules.data_size if axis != ax_m else ms)
        if spec[dim] is None and _divisible(shape[dim], size):
            spec[dim] = axis
            return True
        return False

    stacked = path.startswith(("layers", "dense0", "extra"))
    base = 1 if stacked else 0          # skip the scan-stack dim

    def d(i):                            # logical dim index
        return base + i

    leaf = path.split("/")[-1]

    rank = len(shape) - base             # logical (unstacked) rank

    if leaf == "embed" or path.endswith("embed"):
        put(0, ax_m)                     # vocab
    elif leaf == "head":
        put(1, ax_m)                     # [d, vocab]
    elif leaf in ("wq", "wk", "wv"):
        put(d(1), ax_m)                  # [d, H, dh] → heads
    elif leaf == "wo":
        put(d(0), ax_m)                  # [H, dh, d] → heads
    elif leaf == "w_in" and rank == 4:   # expert stack [E, d, g, ff]
        put(d(0), ax_m)                  # experts (EP)
        if rules.fsdp and rules.expert_fsdp_dim != "none":
            put(d(1) if rules.expert_fsdp_dim == "contract" else d(3),
                rules.data_axes)
    elif leaf == "w_out" and rank == 3 and "moe" in path:
        put(d(0), ax_m)                  # [E, ff, d]
        if rules.fsdp and rules.expert_fsdp_dim != "none":
            put(d(1), rules.data_axes)   # ff: contraction dim of the 2nd mm
    elif leaf == "w_in":                 # dense MLP [d, g, ff]
        put(d(2), ax_m)
    elif leaf == "w_out":                # dense MLP [ff, d]
        put(d(0), ax_m)
    elif leaf == "router":
        pass                             # small: replicate
    elif leaf in ("in_proj", "out_proj", "in_x", "in_gate", "out",
                  "w_a", "w_i"):
        put(d(1), ax_m)                  # project wide dim
    if rules.fsdp and all(s is None for s in spec):
        # ZeRO fallback: biggest dim on data axes if divisible.
        dims = sorted(range(base, len(shape)), key=lambda i: -shape[i])
        for i in dims:
            if _divisible(shape[i], rules.data_size):
                spec[i] = rules.data_axes
                break
    return P(*spec)


def param_pspecs(rules: ShardingRules, params: Any) -> Any:
    """PartitionSpec pytree matching ``params``."""
    def one(path, leaf):
        p = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path)
        return _spec_for_param(rules, p, leaf.shape)

    return jax.tree_util.tree_map_with_path(one, params)


def cache_pspec(rules: ShardingRules, cache: Any) -> Any:
    """KV/state caches: batch on data axes; the KV sequence dim on the model
    axis when kv_heads can't use it (the 32k decode memory fix)."""
    dax = rules.data_axes
    ax_m = rules.model_axis
    ms = rules.model_size

    def one(path, leaf):
        shape = leaf.shape
        names = [str(getattr(k, "key", "")) for k in path]
        spec = [None] * len(shape)
        # layouts: attn k/v [L, B, S, Hkv, dh]; ssm [L, B, H, N, P];
        # rglru h [L, B, W]; conv [L, B, w, C]
        if "k" in names or "v" in names:
            if _divisible(shape[1], rules.data_size):
                spec[1] = dax
            if _divisible(shape[3], ms):
                spec[3] = ax_m           # kv heads
            elif _divisible(shape[2], ms):
                spec[2] = ax_m           # cache sequence
        else:
            if len(shape) > 1 and _divisible(shape[1], rules.data_size):
                spec[1] = dax
            for i in range(2, len(shape)):
                if _divisible(shape[i], ms):
                    spec[i] = ax_m
                    break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, cache)


def batch_specs_sharded(rules: ShardingRules, batch_specs: Dict) -> Dict:
    """Batch inputs: leading batch dim over the data axes."""
    def one(s):
        spec = [None] * len(s.shape)
        if _divisible(s.shape[0], rules.data_size):
            spec[0] = rules.data_axes
        return jax.ShapeDtypeStruct(
            s.shape, s.dtype,
            sharding=NamedSharding(rules.mesh, P(*spec)))

    return jax.tree.map(one, batch_specs)


def opt_pspecs(rules: ShardingRules, opt_state: Any, params: Any) -> Any:
    """Optimizer-state placement: f32 moments mirror their parameter's spec;
    int8-quantized moments ({"q","scale"}) shard blocks over the data axes
    (ZeRO-style)."""
    pspecs = param_pspecs(rules, params)

    def moments(tree):
        def one(path, leaf):
            names = [str(getattr(k, "key", "")) for k in path]
            param_path = [k for k in path
                          if str(getattr(k, "key", "")) not in ("q", "scale")]
            sub = pspecs
            for k in param_path:
                key = getattr(k, "key", getattr(k, "idx", None))
                sub = sub[key]
            if names and names[-1] == "q":
                return sub                         # int8 q mirrors the param
            if names and names[-1] == "scale":
                # scale is param.shape[:-1] + (nb,): drop the last-dim entry.
                dims = list(sub) + [None] * (len(leaf.shape) - len(sub))
                dims = dims[: len(leaf.shape)]
                dims[-1] = None
                return P(*dims)
            return sub                             # f32 moment mirrors param

        return jax.tree_util.tree_map_with_path(one, tree)

    return {
        "step": P(),
        "m": moments(opt_state["m"]),
        "v": moments(opt_state["v"]),
    }


def shardings_for(rules: ShardingRules, specs: Any):
    return jax.tree.map(
        lambda s: NamedSharding(rules.mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))
