"""The composable model: one class covering all ten assigned architectures.

Layer stacks are *scanned* (stacked parameter pytrees + ``lax.scan``) so HLO
size and compile time are depth-independent — essential for the 61-layer MoE
dry-runs.  Heterogeneous stacks (kimi's leading dense layer, recurrentgemma's
(rec, rec, attn) pattern groups) scan the homogeneous part and apply the
remainder unstacked.

API (all pure functions of params):
  init(rng) → params                      (works under jax.eval_shape)
  loss(params, batch) → (loss, metrics)   train forward
  init_cache(batch, max_seq) → cache
  prefill(params, batch, cache) → (logits_last, cache)
  decode(params, tokens, pos, cache) → (logits, cache)
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .blocks import (
    attn_apply, attn_cache, attn_params,
    mamba_apply, mamba_cache, mamba_params,
    moe_apply, moe_params,
    rglru_apply, rglru_cache, rglru_params,
)
from .layers import _init, mlp, mlp_params, rmsnorm


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        # Optional activation-sharding pin applied to the residual stream at
        # every layer boundary (set by the launcher, which knows the mesh).
        # Without it GSPMD can let the MoE group reshape steer the whole
        # residual stream to replicated-batch layouts (§Perf iteration #9).
        self.act_constraint = None

    # ------------------------------------------------------------------ init
    def init(self, rng) -> Dict:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        ks = jax.random.split(rng, 8)
        params: Dict = {
            "embed": _init(ks[0], (cfg.vocab, cfg.d_model), cfg.d_model, dt),
            "final_norm": jnp.zeros((cfg.d_model,), dt),
        }
        if not cfg.tie_embeddings:
            params["head"] = _init(
                ks[1], (cfg.d_model, cfg.vocab), cfg.d_model, dt)
        if cfg.frontend == "frames":
            params["frontend_proj"] = _init(
                ks[2], (cfg.d_model, cfg.d_model), cfg.d_model, dt)

        if cfg.family == "ssm":
            params["layers"] = _stack_init(
                ks[3], cfg.n_layers, lambda r: self._ssm_layer(r))
        elif cfg.family == "hybrid":
            n_grp, rem = self._hybrid_split()
            params["layers"] = _stack_init(
                ks[3], n_grp, lambda r: self._hybrid_group(r))
            if rem:
                params["extra"] = _stack_init(
                    ks[4], rem, lambda r: self._rec_layer(r))
        else:
            n_dense = cfg.first_dense_layers
            n_stack = cfg.n_layers - n_dense
            params["layers"] = _stack_init(
                ks[3], n_stack, lambda r: self._tf_layer(r, moe=cfg.is_moe))
            if n_dense:
                params["dense0"] = _stack_init(
                    ks[4], n_dense, lambda r: self._tf_layer(r, moe=False))
        return params

    # layer param builders ---------------------------------------------------
    def _tf_layer(self, rng, *, moe: bool) -> Dict:
        cfg = self.cfg
        k1, k2 = jax.random.split(rng)
        dt = jnp.dtype(cfg.dtype)
        p = {
            "ln1": jnp.zeros((cfg.d_model,), dt),
            "attn": attn_params(k1, cfg),
            "ln2": jnp.zeros((cfg.d_model,), dt),
        }
        if moe:
            p["moe"] = moe_params(k2, cfg)
        else:
            ff = cfg.moe_dense_d_ff or cfg.d_ff
            p["mlp"] = mlp_params(k2, cfg.d_model, ff, cfg.act, dt)
        return p

    def _rec_layer(self, rng) -> Dict:
        cfg = self.cfg
        k1, k2 = jax.random.split(rng)
        dt = jnp.dtype(cfg.dtype)
        return {
            "ln1": jnp.zeros((cfg.d_model,), dt),
            "rec": rglru_params(k1, cfg),
            "ln2": jnp.zeros((cfg.d_model,), dt),
            "mlp": mlp_params(k2, cfg.d_model, cfg.d_ff, cfg.act, dt),
        }

    def _ssm_layer(self, rng) -> Dict:
        cfg = self.cfg
        return {
            "ln": jnp.zeros((cfg.d_model,), jnp.dtype(cfg.dtype)),
            "mamba": mamba_params(rng, cfg),
        }

    def _hybrid_group(self, rng) -> Dict:
        ks = jax.random.split(rng, len(self.cfg.block_pattern))
        grp = {}
        for i, (kind, kr) in enumerate(zip(self.cfg.block_pattern, ks)):
            grp[f"b{i}"] = (self._rec_layer(kr) if kind == "rec"
                            else self._tf_layer(kr, moe=False))
        return grp

    def _hybrid_split(self) -> Tuple[int, int]:
        g = len(self.cfg.block_pattern)
        return self.cfg.n_layers // g, self.cfg.n_layers % g

    # --------------------------------------------------------------- forward
    def _embed_inputs(self, params, batch) -> Tuple[jnp.ndarray, int]:
        """Returns (x [B, S, d], prefix_len)."""
        cfg = self.cfg
        if cfg.frontend == "frames":
            x = batch["frames"].astype(jnp.dtype(cfg.dtype))
            x = jnp.einsum("bsd,de->bse", x, params["frontend_proj"])
            return x, 0
        emb = params["embed"][batch["tokens"]]
        if cfg.embed_scale:
            emb = emb * jnp.asarray(
                math.sqrt(cfg.d_model), emb.dtype)
        if cfg.frontend == "patches" and "patches" in batch:
            patches = batch["patches"].astype(emb.dtype)
            x = jnp.concatenate([patches, emb], axis=1)
            return x, cfg.n_frontend_tokens
        return emb, 0

    def _unembed(self, params, x) -> jnp.ndarray:
        cfg = self.cfg
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        w = (params["embed"].T if cfg.tie_embeddings else params["head"])
        return jnp.einsum("bsd,dv->bsv", x, w).astype(jnp.float32)

    def _layer_fwd(self, lp, x, kind, *, prefix=0, cache=None, pos=None):
        """One layer; returns (x, new_cache, aux)."""
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        if kind == "ssm":
            h, nc = mamba_apply(cfg, lp["mamba"],
                                rmsnorm(x, lp["ln"], cfg.norm_eps),
                                cache=cache, cache_pos=pos)
            return x + h, nc, aux
        if kind == "rec":
            h, nc = rglru_apply(cfg, lp["rec"],
                                rmsnorm(x, lp["ln1"], cfg.norm_eps),
                                cache=cache, cache_pos=pos)
            x = x + h
            x = x + mlp(rmsnorm(x, lp["ln2"], cfg.norm_eps), lp["mlp"], cfg.act)
            return x, nc, aux
        # transformer layer (attn + mlp/moe)
        window = cfg.local_window if kind == "attn_local" else 0
        h, nc = attn_apply(cfg, lp["attn"],
                           rmsnorm(x, lp["ln1"], cfg.norm_eps),
                           window=window, prefix=prefix,
                           cache=cache, cache_pos=pos)
        x = x + h
        y = rmsnorm(x, lp["ln2"], cfg.norm_eps)
        if "moe" in lp:
            h2, aux = moe_apply(cfg, lp["moe"], y)
        else:
            h2 = mlp(y, lp["mlp"], cfg.act)
        return x + h2, nc, aux

    def _run_stack(self, params, x, *, prefix=0, cache=None, pos=None):
        """All layers; returns (x, new_cache, aux_sum)."""
        cfg = self.cfg
        new_cache: Dict = {}
        aux_tot = jnp.zeros((), jnp.float32)

        def scan_over(stack_p, kind, x, cache_stack):
            nonlocal aux_tot

            def f(carry, inp):
                xc, auxc = carry
                if self.act_constraint is not None:
                    xc = self.act_constraint(xc)
                if cache_stack is None:
                    lp, c = inp, None
                else:
                    lp, c = inp
                if kind == "group":
                    nc = {}
                    for i, bk in enumerate(cfg.block_pattern):
                        key = f"b{i}"
                        kk = "rec" if bk == "rec" else "attn_local"
                        xc, nci, aux_i = self._layer_fwd(
                            lp[key], xc, kk, prefix=prefix,
                            cache=None if c is None else c[key], pos=pos)
                        nc[key] = nci
                        auxc = auxc + aux_i
                else:
                    xc, nc, aux_i = self._layer_fwd(
                        lp, xc, kind, prefix=prefix, cache=c, pos=pos)
                    auxc = auxc + aux_i
                return (xc, auxc), nc

            f_ = jax.checkpoint(f) if cfg.remat == "layer" else f
            xs = stack_p if cache_stack is None else (stack_p, cache_stack)
            if cfg.unroll_layers:
                # Python-loop unroll: used by the calibrated cost model so
                # per-layer FLOPs/bytes/collectives are visible in the HLO
                # (XLA cost analysis counts while-loop bodies once).
                n = jax.tree.leaves(stack_p)[0].shape[0]
                carry = (x, aux_tot)
                ncs_list = []
                for i in range(n):
                    xi = jax.tree.map(lambda a: a[i], xs)
                    carry, nc = f_(carry, xi)
                    ncs_list.append(nc)
                (x, aux) = carry
                ncs = (jax.tree.map(lambda *a: jnp.stack(a), *ncs_list)
                       if ncs_list and ncs_list[0] is not None else None)
            else:
                (x, aux), ncs = jax.lax.scan(f_, (x, aux_tot), xs)
            aux_tot = aux
            return x, ncs

        if cfg.family == "ssm":
            x, nc = scan_over(params["layers"], "ssm", x,
                              None if cache is None else cache["layers"])
            new_cache["layers"] = nc
        elif cfg.family == "hybrid":
            x, nc = scan_over(params["layers"], "group", x,
                              None if cache is None else cache["layers"])
            new_cache["layers"] = nc
            if "extra" in params:
                x, nc2 = scan_over(params["extra"], "rec", x,
                                   None if cache is None else cache["extra"])
                new_cache["extra"] = nc2
        else:
            if "dense0" in params:
                x, nc0 = scan_over(params["dense0"], "attn", x,
                                   None if cache is None else cache["dense0"])
                new_cache["dense0"] = nc0
            x, nc = scan_over(params["layers"], "attn", x,
                              None if cache is None else cache["layers"])
            new_cache["layers"] = nc
        return x, (new_cache if cache is not None else None), aux_tot

    # ----------------------------------------------------------------- train
    def logits(self, params, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
        x, prefix = self._embed_inputs(params, batch)
        x, _, aux = self._run_stack(params, x, prefix=prefix)
        return self._unembed(params, x), aux

    def loss(self, params, batch) -> Tuple[jnp.ndarray, Dict]:
        cfg = self.cfg
        logits, aux = self.logits(params, batch)
        if cfg.frontend == "frames":
            labels = batch["labels"]
            ce = _xent(logits, labels).mean()
        else:
            tokens = batch["tokens"]
            txt_logits = logits[:, cfg.n_frontend_tokens:] \
                if cfg.frontend == "patches" else logits
            ce = _xent(txt_logits[:, :-1], tokens[:, 1:]).mean()
        loss = ce + 1e-2 * aux
        return loss, {"ce": ce, "aux": aux}

    # ----------------------------------------------------------------- serve
    def init_cache(self, batch: int, max_seq: int) -> Dict:
        cfg = self.cfg
        cache: Dict = {}
        if cfg.family == "ssm":
            cache["layers"] = _stack_cache(
                cfg.n_layers, lambda: mamba_cache(cfg, batch))
        elif cfg.family == "hybrid":
            n_grp, rem = self._hybrid_split()

            def group_cache():
                g = {}
                for i, bk in enumerate(cfg.block_pattern):
                    g[f"b{i}"] = (rglru_cache(cfg, batch) if bk == "rec"
                                  else attn_cache(cfg, batch, max_seq))
                return g

            cache["layers"] = _stack_cache(n_grp, group_cache)
            if rem:
                cache["extra"] = _stack_cache(
                    rem, lambda: rglru_cache(cfg, batch))
        else:
            n_dense = cfg.first_dense_layers
            if n_dense:
                cache["dense0"] = _stack_cache(
                    n_dense, lambda: attn_cache(cfg, batch, max_seq))
            cache["layers"] = _stack_cache(
                cfg.n_layers - n_dense,
                lambda: attn_cache(cfg, batch, max_seq))
        return cache

    def prefill(self, params, batch, cache) -> Tuple[jnp.ndarray, Dict]:
        x, prefix = self._embed_inputs(params, batch)
        x, cache, _ = self._run_stack(params, x, prefix=prefix, cache=cache,
                                      pos=jnp.int32(0))
        return self._unembed(params, x[:, -1:]), cache

    def decode(self, params, tokens, pos, cache) -> Tuple[jnp.ndarray, Dict]:
        """One decode step: tokens [B, 1], pos scalar int32 (absolute)."""
        emb = params["embed"][tokens]
        if self.cfg.embed_scale:
            emb = emb * jnp.asarray(math.sqrt(self.cfg.d_model), emb.dtype)
        x, cache, _ = self._run_stack(params, emb, cache=cache, pos=pos)
        return self._unembed(params, x), cache


def _xent(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return logz - gold


def _stack_init(rng, n: int, one_fn):
    return jax.vmap(one_fn)(jax.random.split(rng, n))


def _stack_cache(n: int, one_fn):
    one = one_fn()
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape).copy(),
                        one)
