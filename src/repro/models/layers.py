"""Shared transformer building blocks: RMSNorm, RoPE, GQA attention (chunked
streaming softmax for long sequences), gated MLPs.

All parameters are plain dicts of jnp arrays; all functions are pure.  The
streaming attention is the XLA twin of the Pallas flash kernel (same running
(m, l, acc) math) so the 32k/500k dry-run shapes never materialise an S×S
score matrix.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------- #
# Norms / RoPE                                                                 #
# --------------------------------------------------------------------------- #

def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * (1.0 + w.astype(jnp.float32))
            ).astype(x.dtype)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x [..., S, H, d]; positions [..., S] (broadcastable int32)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs        # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- #
# Attention                                                                    #
# --------------------------------------------------------------------------- #

def _mask_block(q_pos, k_pos, *, causal: bool, window: int, prefix: int):
    """Boolean mask [bq, bk] for absolute positions."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        c = k_pos[None, :] <= q_pos[:, None]
        if prefix > 0:
            c = c | (k_pos[None, :] < prefix)
        m = m & c
    if window > 0:
        m = m & (k_pos[None, :] > q_pos[:, None] - window)
    return m


def attention(
    q: jnp.ndarray,            # [B, Sq, Hq, d]
    k: jnp.ndarray,            # [B, Sk, Hkv, d]
    v: jnp.ndarray,            # [B, Sk, Hkv, d]
    *,
    causal: bool = True,
    window: int = 0,
    prefix: int = 0,
    q_offset=0,                # absolute position of q[0] (int or traced)
    kv_valid=None,             # dynamic valid KV length (decode)
    chunk: int = 0,            # 0 → unchunked
) -> jnp.ndarray:
    """GQA attention over [B, S, H, d] layouts.  ``chunk > 0`` streams KV (and
    Q for training shapes) so peak memory is O(S·chunk)."""
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    group = hq // hkv
    scale = 1.0 / math.sqrt(d)

    if chunk and sk > chunk:
        return _attention_chunked(
            q, k, v, causal=causal, window=window, prefix=prefix,
            q_offset=q_offset, kv_valid=kv_valid, chunk=chunk, scale=scale,
        )

    qh = q.reshape(b, sq, hkv, group, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qh, k,
                   preferred_element_type=jnp.float32) * scale
    q_pos = q_offset + jnp.arange(sq)
    k_pos = jnp.arange(sk)
    mask = _mask_block(q_pos, k_pos, causal=causal, window=window,
                       prefix=prefix)
    if kv_valid is not None:
        mask = mask & (k_pos[None, :] < kv_valid)
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, hq, d).astype(q.dtype)


def _attention_chunked(q, k, v, *, causal, window, prefix, q_offset, kv_valid,
                       chunk, scale):
    """Streaming-softmax attention: scan over KV chunks (and over Q chunks
    when Sq is large) with running (m, l, acc) — flash attention in XLA."""
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    group = hq // hkv

    n_kc = -(-sk // chunk)
    sk_pad = n_kc * chunk
    if sk_pad != sk:
        k = jnp.pad(k, [(0, 0), (0, sk_pad - sk), (0, 0), (0, 0)])
        v = jnp.pad(v, [(0, 0), (0, sk_pad - sk), (0, 0), (0, 0)])
    kc = k.reshape(b, n_kc, chunk, hkv, d)
    vc = v.reshape(b, n_kc, chunk, hkv, d)
    valid = jnp.minimum(kv_valid, sk) if kv_valid is not None else sk

    def q_block(qb, q_pos):
        # Keep q/k/v in their storage dtype (bf16): any resharding collective
        # GSPMD inserts then moves half the bytes; the dots still accumulate
        # in f32 via preferred_element_type (§Perf iteration #7).
        qb = qb.reshape(b, -1, hkv, group, d)
        sq_b = qb.shape[1]

        # Rematerialise each KV chunk's scores in the backward pass instead of
        # saving the O(S·chunk) score/probability matrices of every step.
        @jax.checkpoint
        def step(carry, inp):
            m, l, acc = carry
            kb, vb, j = inp
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            k_pos = j * chunk + jnp.arange(chunk)
            mask = _mask_block(q_pos, k_pos, causal=causal, window=window,
                               prefix=prefix)
            mask = mask & (k_pos[None, :] < valid)
            s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(mask[None, None, None], p, 0.0)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, group, sq_b), -1e30, jnp.float32)
        l0 = jnp.zeros((b, hkv, group, sq_b), jnp.float32)
        a0 = jnp.zeros((b, hkv, group, sq_b, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            step, (m0, l0, a0),
            (kc.swapaxes(0, 1), vc.swapaxes(0, 1), jnp.arange(n_kc)),
        )
        l = jnp.where(l == 0.0, 1.0, l)
        out = (acc / l[..., None])                       # [b,hkv,g,sq_b,d]
        return out.transpose(0, 3, 1, 2, 4).reshape(b, sq_b, hq, d)

    if sq <= chunk:
        q_pos = q_offset + jnp.arange(sq)
        return q_block(q, q_pos).astype(q.dtype)

    n_qc = -(-sq // chunk)
    sq_pad = n_qc * chunk
    if sq_pad != sq:
        q = jnp.pad(q, [(0, 0), (0, sq_pad - sq), (0, 0), (0, 0)])
    qcs = q.reshape(b, n_qc, chunk, hq, d).swapaxes(0, 1)

    def qstep(_, inp):
        qb, i = inp
        q_pos = q_offset + i * chunk + jnp.arange(chunk)
        return None, q_block(qb, q_pos)

    _, outs = jax.lax.scan(qstep, None, (qcs, jnp.arange(n_qc)))
    out = outs.swapaxes(0, 1).reshape(b, sq_pad, hq, d)
    return out[:, :sq].astype(q.dtype)


# --------------------------------------------------------------------------- #
# MLPs                                                                         #
# --------------------------------------------------------------------------- #

def mlp(x: jnp.ndarray, p: dict, act: str) -> jnp.ndarray:
    """Gated (swiglu/geglu) or plain-gelu MLP; params:
    gated: {w_in [d, 2, ff], w_out [ff, d]}; plain: {w_in [d, 1, ff], w_out}."""
    w_in, w_out = p["w_in"], p["w_out"]
    h = jnp.einsum("...d,dgf->...gf", x, w_in)
    if w_in.shape[1] == 2:
        gate, up = h[..., 0, :], h[..., 1, :]
        g = jax.nn.silu(gate) if act == "swiglu" else jax.nn.gelu(gate)
        h = g * up
    else:
        h = jax.nn.gelu(h[..., 0, :])
    return jnp.einsum("...f,fd->...d", h, w_out)


def mlp_params(rng, d: int, ff: int, act: str, dtype) -> dict:
    gates = 1 if act == "gelu" else 2
    k1, k2 = jax.random.split(rng)
    return {
        "w_in": _init(k1, (d, gates, ff), d, dtype),
        "w_out": _init(k2, (ff, d), ff, dtype),
    }


def _init(rng, shape, fan_in, dtype):
    return (jax.random.normal(rng, shape, jnp.float32)
            / math.sqrt(fan_in)).astype(dtype)
