"""Per-family layer blocks: GQA attention (w/ KV cache), MoE with PEMS-style
capacity dispatch, Mamba-2 SSD, and RG-LRU recurrent blocks.

Every block has ``<name>_params(rng, cfg)`` and a pure ``<name>_apply``; all
are scan-compatible (stacked leading layer dim) and decode-capable (cache
slices threaded through the scan).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import _init, attention, mlp, mlp_params, rmsnorm, rope


# =========================================================================== #
# GQA attention block                                                          #
# =========================================================================== #

def attn_params(rng, cfg) -> dict:
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(rng, 4)
    dt = jnp.dtype(cfg.dtype)
    p = {
        "wq": _init(ks[0], (d, hq, dh), d, dt),
        "wk": _init(ks[1], (d, hkv, dh), d, dt),
        "wv": _init(ks[2], (d, hkv, dh), d, dt),
        "wo": _init(ks[3], (hq, dh, d), hq * dh, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq, dh), dt)
        p["bk"] = jnp.zeros((hkv, dh), dt)
        p["bv"] = jnp.zeros((hkv, dh), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((dh,), dt)
        p["k_norm"] = jnp.zeros((dh,), dt)
    return p


def attn_apply(
    cfg,
    p: dict,
    x: jnp.ndarray,              # [B, S, d]
    *,
    window: int = 0,
    prefix: int = 0,
    cache: Optional[dict] = None,   # {"k","v": [B, Smax, Hkv, dh]}
    cache_pos=None,                 # scalar position of x[:, 0]
) -> Tuple[jnp.ndarray, Optional[dict]]:
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)

    offset = 0 if cache_pos is None else cache_pos
    pos = offset + jnp.arange(s)
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)

    if cache is None:
        out = attention(
            q, k, v, causal=cfg.causal, window=window, prefix=prefix,
            chunk=cfg.attn_chunk,
        )
        new_cache = None
    else:
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, offset, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, offset, axis=1)
        new_cache = {"k": ck, "v": cv}
        out = attention(
            q, ck, cv, causal=cfg.causal, window=window, prefix=prefix,
            q_offset=offset, kv_valid=offset + s, chunk=cfg.attn_chunk,
        )
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache


def attn_cache(cfg, batch: int, max_seq: int) -> dict:
    dt = jnp.dtype(cfg.dtype)
    shape = (batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


# =========================================================================== #
# MoE block — PEMS-style capacity dispatch                                     #
# =========================================================================== #
#
# Experts are the thesis' virtual processors: tokens are bucketised by
# destination expert with the same grouping primitive the BSP apps use
# (group-by-destination + capacity ω), delivered "directly" into per-expert
# buffers, processed expert-by-expert, and combined back.  Under expert
# sharding the dispatch lowers to the all-to-all the thesis' Alltoallv
# performs across real processors.

def moe_params(rng, cfg) -> dict:
    d, e, ff = cfg.d_model, cfg.n_experts, cfg.d_ff
    ks = jax.random.split(rng, 5)
    dt = jnp.dtype(cfg.dtype)
    gates = 1 if cfg.act == "gelu" else 2
    p = {
        "router": _init(ks[0], (d, e), d, jnp.float32),
        "w_in": _init(ks[1], (e, d, gates, ff), d, dt),
        "w_out": _init(ks[2], (e, ff, d), ff, dt),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_params(
            ks[3], d, cfg.d_ff * cfg.n_shared_experts, cfg.act, dt)
    if cfg.moe_dense_residual:
        p["dense"] = mlp_params(
            ks[4], d, cfg.moe_dense_d_ff or cfg.d_ff, cfg.act, dt)
    return p


def moe_apply(cfg, p: dict, x: jnp.ndarray,
              n_groups: int = 0) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output [B, S, d], aux load-balance loss).

    Hierarchical dispatch (the thesis' real/virtual processor split): tokens
    are partitioned into ``n_groups`` data-parallel groups (one per DP shard);
    each group bucketises its tokens by destination expert under a local
    capacity ω and the grouped einsum runs with experts sharded on the model
    axis.  Every intermediate keeps a leading group dim, so GSPMD keeps the
    dispatch sharded — no T·K×d replicated scatter.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    n_groups = n_groups or getattr(cfg, "moe_groups", 1) or 1
    n_groups = min(n_groups, t)
    while t % n_groups:
        n_groups -= 1
    tg = t // n_groups
    cap = max(1, int(math.ceil(tg * k / e * cfg.capacity_factor)))
    xg = x.reshape(n_groups, tg, d)

    def group_dispatch(xf):                                   # [tg, d]
        logits = (xf.astype(jnp.float32) @ p["router"])       # [tg, E]
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, sel = jax.lax.top_k(logits, k)             # [tg, K]
        weights = jax.nn.softmax(gate_vals, axis=-1)

        density = jnp.mean(
            jax.nn.one_hot(sel[:, 0], e, dtype=jnp.float32), axis=0)
        aux = e * jnp.sum(density * probs.mean(axis=0))

        flat_e = sel.reshape(-1)                              # [tg·K]
        flat_w = weights.reshape(-1)
        tok_of = jnp.repeat(jnp.arange(tg, dtype=jnp.int32), k)

        order = jnp.argsort(flat_e, stable=True)
        se = flat_e[order]
        start = jnp.searchsorted(se, jnp.arange(e, dtype=se.dtype))
        pos = jnp.arange(tg * k, dtype=jnp.int32) - start[se].astype(jnp.int32)
        keep = pos < cap                                      # token dropping
        pos_c = jnp.minimum(pos, cap - 1)

        tok_sorted = tok_of[order]
        w_sorted = flat_w[order]
        xe = jnp.zeros((e, cap, d), x.dtype)
        src = jnp.where(keep[:, None], xf[tok_sorted], 0)
        xe = xe.at[se, pos_c].set(src.astype(x.dtype))
        return xe, (se, pos_c, keep, tok_sorted, w_sorted, aux)

    xe, (se, pos_c, keep, tok_sorted, w_sorted, aux) = jax.vmap(
        group_dispatch)(xg)                                   # [G, E, cap, d]

    # ---- expert compute (grouped matmul; experts on the model axis) --------
    h = jnp.einsum("gecd,edGf->gecGf", xe, p["w_in"])
    # dims: (group, E, cap, gates, ff)
    if p["w_in"].shape[2] == 2:
        gte = (jax.nn.silu(h[..., 0, :]) if cfg.act == "swiglu"
               else jax.nn.gelu(h[..., 0, :]))
        h = gte * h[..., 1, :]
    else:
        h = jax.nn.gelu(h[..., 0, :])
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_out"])          # [G, E, cap, d]

    # ---- combine (direct delivery back to token slots) ---------------------
    def group_combine(ye_g, se_g, pos_g, keep_g, tok_g, w_g):
        contrib = ye_g[se_g, pos_g] * (w_g * keep_g)[:, None].astype(ye_g.dtype)
        return jnp.zeros((tg, d), ye_g.dtype).at[tok_g].add(contrib)

    y = jax.vmap(group_combine)(ye, se, pos_c, keep, tok_sorted,
                                w_sorted).reshape(b, s, d)

    if "shared" in p:
        y = y + mlp(x, p["shared"], cfg.act)
    if "dense" in p:
        y = y + mlp(x, p["dense"], cfg.act)
    return y.astype(x.dtype), aux.mean()


def moe_apply_dense_oracle(cfg, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Reference combine-over-all-experts path (tests only — O(E) compute)."""
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf.astype(jnp.float32) @ p["router"]
    gate_vals, sel = jax.lax.top_k(logits, cfg.top_k)
    weights = jax.nn.softmax(gate_vals, axis=-1)

    h = jnp.einsum("td,edgf->tegf", xf, p["w_in"])
    if p["w_in"].shape[2] == 2:
        g = (jax.nn.silu(h[..., 0, :]) if cfg.act == "swiglu"
             else jax.nn.gelu(h[..., 0, :]))
        h = g * h[..., 1, :]
    else:
        h = jax.nn.gelu(h[..., 0, :])
    ye = jnp.einsum("tef,efd->ted", h, p["w_out"])            # [T, E, d]

    comb = jnp.zeros(logits.shape, ye.dtype)
    comb = jax.vmap(lambda c, s_, w_: c.at[s_].set(w_.astype(ye.dtype))
                    )(comb, sel, weights)
    y = jnp.einsum("te,ted->td", comb, ye).reshape(b, s, d)
    if "shared" in p:
        y = y + mlp(x, p["shared"], cfg.act)
    if "dense" in p:
        y = y + mlp(x, p["dense"], cfg.act)
    return y.astype(x.dtype)


# =========================================================================== #
# Mamba-2 (SSD) block                                                          #
# =========================================================================== #

def mamba_params(rng, cfg) -> dict:
    d, din, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = din + 2 * n
    ks = jax.random.split(rng, 4)
    dt = jnp.dtype(cfg.dtype)
    return {
        "in_proj": _init(ks[0], (d, 2 * din + 2 * n + h), d, dt),
        "conv_w": _init(ks[1], (cfg.ssm_conv, conv_dim), cfg.ssm_conv, dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "A_log": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "norm_w": jnp.zeros((din,), dt),
        "out_proj": _init(ks[2], (din, d), din, dt),
    }


def _ssd_chunked_jnp(x, dtv, A, Bm, Cm, chunk: int):
    """Pure-jnp twin of the ssd_scan kernel: scan over chunks with the carried
    state (identical math; used for XLA-only backends / dry-run lowering)."""
    b, h, s, pdim = x.shape
    n = Bm.shape[-1]
    nc = -(-s // chunk)
    sp = nc * chunk
    if sp != s:
        x = jnp.pad(x, [(0, 0), (0, 0), (0, sp - s), (0, 0)])
        dtv = jnp.pad(dtv, [(0, 0), (0, 0), (0, sp - s)])
        Bm = jnp.pad(Bm, [(0, 0), (0, sp - s), (0, 0)])
        Cm = jnp.pad(Cm, [(0, 0), (0, sp - s), (0, 0)])

    xc = x.reshape(b, h, nc, chunk, pdim).transpose(2, 0, 1, 3, 4)
    dc = dtv.reshape(b, h, nc, chunk).transpose(2, 0, 1, 3)
    Bc = Bm.reshape(b, nc, chunk, n).transpose(1, 0, 2, 3)
    Cc = Cm.reshape(b, nc, chunk, n).transpose(1, 0, 2, 3)

    row = jnp.arange(chunk)[:, None]
    col = jnp.arange(chunk)[None, :]
    causal = row >= col

    def step(S, inp):
        xb, db, Bb, Cb = inp            # [b,h,C,p], [b,h,C], [b,C,n], [b,C,n]
        cdt = jnp.cumsum(db, axis=-1)   # [b,h,C]
        G = jnp.einsum("bin,bjn->bij", Cb, Bb)                  # [b,C,C]
        seg = A[None, :, None, None] * (cdt[..., :, None] - cdt[..., None, :])
        M = jnp.where(causal, jnp.exp(jnp.where(causal, seg, 0.0)), 0.0)
        W = G[:, None] * M * db[..., None, :]                   # [b,h,C,C]
        y_intra = jnp.einsum("bhij,bhjp->bhip", W, xb)
        decay_t = jnp.exp(A[None, :, None] * cdt)               # [b,h,C]
        y_carry = decay_t[..., None] * jnp.einsum(
            "bin,bhnp->bhip", Cb, S)
        wt = jnp.exp(A[None, :, None] * (cdt[..., -1:] - cdt)) * db
        S_new = (jnp.exp(A[None, :] * cdt[..., -1])[..., None, None] * S
                 + jnp.einsum("bin,bhip->bhnp", Bb, xb * wt[..., None]))
        return S_new, y_intra + y_carry

    S0 = jnp.zeros((b, h, n, pdim), jnp.float32)
    S_fin, ys = jax.lax.scan(step, S0, (
        xc.astype(jnp.float32), dc.astype(jnp.float32),
        Bc.astype(jnp.float32), Cc.astype(jnp.float32)))
    y = ys.transpose(1, 2, 0, 3, 4).reshape(b, h, sp, pdim)
    return y[:, :, :s], S_fin


def mamba_apply(cfg, p: dict, x: jnp.ndarray, *,
                cache: Optional[dict] = None,
                cache_pos=None) -> Tuple[jnp.ndarray, Optional[dict]]:
    b, s, d = x.shape
    din, n, h, pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    cw = cfg.ssm_conv

    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z = zxbcdt[..., :din]
    xBC = zxbcdt[..., din:din + din + 2 * n]
    dtv = zxbcdt[..., -h:].astype(jnp.float32)

    if cache is None or s > 1:
        # Causal depthwise conv over the sequence (prefill keeps the raw tail
        # as the next conv window).
        raw = xBC
        pad = jnp.pad(xBC, [(0, 0), (cw - 1, 0), (0, 0)])
        xBC = sum(pad[:, i:i + s] * p["conv_w"][i] for i in range(cw))
        xBC = jax.nn.silu(xBC + p["conv_b"])
        conv_tail = raw[:, -(cw - 1):] if cache is not None else None
    else:
        # Single-step (s == 1) conv using the cached window.
        prev = cache["conv"]                         # [B, cw-1, conv_dim]
        window = jnp.concatenate([prev, xBC], axis=1)
        out = sum(window[:, i:i + 1] * p["conv_w"][i] for i in range(cw))
        conv_tail = window[:, 1:]
        xBC = jax.nn.silu(out + p["conv_b"])

    xs = xBC[..., :din].reshape(b, s, h, pd).transpose(0, 2, 1, 3)  # [B,H,S,P]
    Bm = xBC[..., din:din + n]
    Cm = xBC[..., din + n:]
    dtv = jax.nn.softplus(dtv + p["dt_bias"]).transpose(0, 2, 1)    # [B,H,S]
    A = -jnp.exp(p["A_log"])

    if cache is None or s > 1:
        y, S_fin = _ssd_chunked_jnp(
            xs.astype(jnp.float32), dtv, A,
            Bm.astype(jnp.float32), Cm.astype(jnp.float32),
            chunk=min(128, max(16, s)),
        )
        new_state = (S_fin.astype(cache["ssm"].dtype)
                     if cache is not None else None)
    else:
        S = cache["ssm"].astype(jnp.float32)        # [B, H, N, P]
        dt1 = dtv[..., 0]                            # [B, H]
        decay = jnp.exp(A[None] * dt1)               # [B, H]
        x1 = xs[:, :, 0].astype(jnp.float32)         # [B, H, P]
        B1 = Bm[:, 0].astype(jnp.float32)            # [B, N]
        C1 = Cm[:, 0].astype(jnp.float32)
        S = (decay[..., None, None] * S
             + dt1[..., None, None] * B1[:, None, :, None] * x1[:, :, None, :])
        y = jnp.einsum("bn,bhnp->bhp", C1, S)[:, :, None].transpose(0, 1, 2, 3)
        y = y.reshape(b, h, 1, pd)
        new_state = S.astype(cache["ssm"].dtype)

    y = y + p["D"][None, :, None, None] * xs.astype(jnp.float32)
    y = y.transpose(0, 2, 1, 3).reshape(b, s, din).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, p["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])

    new_cache = (None if cache is None
                 else {"ssm": new_state, "conv": conv_tail})
    return out, new_cache


def mamba_cache(cfg, batch: int) -> dict:
    din, n = cfg.d_inner, cfg.ssm_state
    return {
        "ssm": jnp.zeros((batch, cfg.ssm_heads, n, cfg.ssm_headdim),
                         jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, din + 2 * n),
                          jnp.dtype(cfg.dtype)),
    }


# =========================================================================== #
# RG-LRU recurrent block (RecurrentGemma / Griffin)                            #
# =========================================================================== #

_RG_C = 8.0


def rglru_params(rng, cfg) -> dict:
    d, w = cfg.d_model, cfg.lru_width
    ks = jax.random.split(rng, 6)
    dt = jnp.dtype(cfg.dtype)
    return {
        "in_x": _init(ks[0], (d, w), d, dt),
        "in_gate": _init(ks[1], (d, w), d, dt),
        "conv_w": _init(ks[2], (4, w), 4, dt),
        "conv_b": jnp.zeros((w,), dt),
        "w_a": _init(ks[3], (w, w), w, dt),
        "b_a": jnp.zeros((w,), jnp.float32),
        "w_i": _init(ks[4], (w, w), w, dt),
        "b_i": jnp.zeros((w,), jnp.float32),
        "lam": jnp.full((w,), 2.0, jnp.float32),   # Λ: a ≈ 0.96^r at init
        "out": _init(ks[5], (w, d), w, dt),
    }


def _lru_chunked_jnp(a, b, chunk: int):
    """Pure-jnp twin of the lru_scan kernel (chunked doubling scan)."""
    bsz, s, d = a.shape
    nc = -(-s // chunk)
    sp = nc * chunk
    if sp != s:
        a = jnp.pad(a, [(0, 0), (0, sp - s), (0, 0)], constant_values=1.0)
        b = jnp.pad(b, [(0, 0), (0, sp - s), (0, 0)])
    ac = a.reshape(bsz, nc, chunk, d).swapaxes(0, 1)
    bc = b.reshape(bsz, nc, chunk, d).swapaxes(0, 1)

    def step(h, inp):
        av, bv = inp
        sft = 1
        while sft < chunk:
            a_prev = jnp.concatenate(
                [jnp.ones_like(av[:, :sft]), av[:, :-sft]], axis=1)
            b_prev = jnp.concatenate(
                [jnp.zeros_like(bv[:, :sft]), bv[:, :-sft]], axis=1)
            mask = (jnp.arange(chunk) >= sft)[None, :, None]
            av, bv = (jnp.where(mask, a_prev * av, av),
                      jnp.where(mask, b_prev * av + bv, bv))
            sft *= 2
        hs = av * h[:, None] + bv
        return hs[:, -1], hs

    h0 = jnp.zeros((bsz, a.shape[-1]), jnp.float32)
    h_fin, ys = jax.lax.scan(step, h0, (ac.astype(jnp.float32),
                                        bc.astype(jnp.float32)))
    return ys.swapaxes(0, 1).reshape(bsz, sp, d)[:, :s], h_fin


def rglru_apply(cfg, p: dict, x: jnp.ndarray, *,
                cache: Optional[dict] = None,
                cache_pos=None) -> Tuple[jnp.ndarray, Optional[dict]]:
    b, s, d = x.shape
    w = cfg.lru_width
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["in_gate"]))
    xr = jnp.einsum("bsd,dw->bsw", x, p["in_x"])

    cw = 4
    if cache is None or s > 1:
        pad = jnp.pad(xr, [(0, 0), (cw - 1, 0), (0, 0)])
        xc = sum(pad[:, i:i + s] * p["conv_w"][i] for i in range(cw))
        xc = xc + p["conv_b"]
        conv_tail = xr[:, -(cw - 1):] if cache is not None else None
    else:
        window = jnp.concatenate([cache["conv"], xr], axis=1)
        xc = sum(window[:, i:i + 1] * p["conv_w"][i] for i in range(cw))
        xc = xc + p["conv_b"]
        conv_tail = window[:, 1:]

    r = jax.nn.sigmoid(
        jnp.einsum("bsw,wv->bsv", xc, p["w_a"]).astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid(
        jnp.einsum("bsw,wv->bsv", xc, p["w_i"]).astype(jnp.float32) + p["b_i"])
    log_a = -_RG_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    gated_x = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-9)) * (
        i * xc.astype(jnp.float32))

    if cache is None or s > 1:
        h, h_fin = _lru_chunked_jnp(a, gated_x, chunk=min(256, max(16, s)))
        new_cache = (None if cache is None else
                     {"h": h_fin.astype(jnp.float32), "conv": conv_tail})
    else:
        h = a * cache["h"][:, None].astype(jnp.float32) + gated_x
        new_cache = {"h": h[:, -1].astype(jnp.float32), "conv": conv_tail}

    out = (h.astype(x.dtype) * gate)
    return jnp.einsum("bsw,wd->bsd", out, p["out"]), new_cache


def rglru_cache(cfg, batch: int) -> dict:
    return {
        "h": jnp.zeros((batch, cfg.lru_width), jnp.float32),
        "conv": jnp.zeros((batch, 3, cfg.lru_width), jnp.dtype(cfg.dtype)),
    }
