"""Model zoo: one composable decoder/encoder covering all ten assigned
architectures (dense GQA, MoE, SSD, RG-LRU hybrid, encoder-only, VLM)."""

from .model import Model

__all__ = ["Model"]
