"""Fault-tolerant checkpointing.

Designed for the preemption model of large TPU fleets:

* **Atomic commit**: state is written to ``step_N.tmp/`` and renamed to
  ``step_N/`` only after every shard file and the manifest are fsync'd —
  a torn write can never be mistaken for a checkpoint.
* **Crash-safe restore**: ``restore_latest`` scans newest→oldest and skips
  any directory whose manifest is missing/invalid (simulated-crash test).
* **Keep-k retention** with the newest always kept.
* **Mesh-shape agnostic**: arrays are saved as full logical arrays plus a
  pytree manifest; ``restore`` re-shards onto whatever mesh the new job has
  (elastic scaling: a 512-chip checkpoint restores onto 256 chips or 8 CPU
  processes — tested).
* **Async save**: the device→host copy happens synchronously (consistency),
  the file write on a background thread (training continues).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pending: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Any, blocking: bool = True) -> str:
        """Snapshot ``state`` (any pytree of arrays) at ``step``."""
        flat, treedef = jax.tree_util.tree_flatten_with_path(state)
        # Device→host transfer now, so training can mutate buffers after.
        host = [(self._key_str(path), np.asarray(leaf)) for path, leaf in flat]
        self.wait()

        def write():
            tmp = os.path.join(self.dir, f"step_{step:012d}.tmp")
            final = os.path.join(self.dir, f"step_{step:012d}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            names = []
            for i, (key, arr) in enumerate(host):
                fn = f"arr_{i:05d}.npy"
                with open(os.path.join(tmp, fn), "wb") as f:
                    np.save(f, arr)
                    f.flush()
                    os.fsync(f.fileno())
                names.append({"key": key, "file": fn,
                              "shape": list(arr.shape),
                              "dtype": str(arr.dtype)})
            manifest = {"step": step, "arrays": names,
                        "time": time.time(), "version": 1}
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(tmp)       # another writer won the race
            else:
                os.replace(tmp, final)   # atomic commit
            self._gc()

        if blocking:
            write()
        else:
            self._pending = threading.Thread(target=write, daemon=True)
            self._pending.start()
        return os.path.join(self.dir, f"step_{step:012d}")

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    # --------------------------------------------------------------- restore
    def restore_latest(self, like: Any = None,
                       shardings: Any = None) -> Optional[Tuple[int, Any]]:
        """Newest complete checkpoint, or None.  ``like`` supplies the pytree
        structure (its leaves are ignored); ``shardings`` optionally re-shards
        every leaf (elastic restore onto a different mesh)."""
        self.wait()
        for step in sorted(self._steps(), reverse=True):
            try:
                return step, self._load(step, like, shardings)
            except Exception:
                continue   # torn/corrupt checkpoint: fall back to older
        return None

    def restore(self, step: int, like: Any = None, shardings: Any = None):
        return self._load(step, like, shardings)

    # ---------------------------------------------------------------- intern
    def _load(self, step: int, like, shardings):
        d = os.path.join(self.dir, f"step_{step:012d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        arrays = []
        for meta in manifest["arrays"]:
            arr = np.load(os.path.join(d, meta["file"]))
            if list(arr.shape) != meta["shape"]:
                raise IOError(f"shape mismatch in {meta['file']}")
            arrays.append(arr)
        if like is None:
            return arrays
        flat, treedef = jax.tree_util.tree_flatten(like)
        if len(flat) != len(arrays):
            raise IOError(
                f"checkpoint has {len(arrays)} leaves, state has {len(flat)}")
        if shardings is not None:
            flat_sh = treedef.flatten_up_to(shardings)
            arrays = [jax.device_put(a, s)
                      for a, s in zip(arrays, flat_sh)]
        else:
            arrays = [jax.device_put(a) for a in arrays]
        return jax.tree_util.tree_unflatten(treedef, arrays)

    def _steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return out

    def _gc(self) -> None:
        steps = sorted(self._steps())
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:012d}"),
                          ignore_errors=True)

    @staticmethod
    def _key_str(path) -> str:
        return jax.tree_util.keystr(path)
