"""Fault-tolerant checkpointing.

Designed for the preemption model of large TPU fleets:

* **Atomic commit**: state is written to ``step_N.tmp/`` and renamed to
  ``step_N/`` only after every shard file and the manifest are fsync'd —
  a torn write can never be mistaken for a checkpoint.
* **Crash-safe restore**: ``restore_latest`` scans newest→oldest and skips
  any directory whose manifest is missing/invalid (simulated-crash test).
* **Keep-k retention** with the newest always kept.
* **Mesh-shape agnostic**: arrays are saved as full logical arrays plus a
  pytree manifest; ``restore`` re-shards onto whatever mesh the new job has
  (elastic scaling: a 512-chip checkpoint restores onto 256 chips or 8 CPU
  processes — tested).
* **Async save**: the device→host copy happens synchronously (consistency),
  the file write on a background thread (training continues).
* **Memmap-aware**: ``np.memmap`` leaves (e.g. a PEMS memmap-backed context
  store) are streamed to/from the checkpoint file in bounded chunks — a
  ``v·mu`` out-of-core store checkpoints and restores without ever being
  materialized on device (or fully in host RAM).  On restore, a memmap leaf
  in ``like`` is filled *in place* and returned as-is.  Note: a non-blocking
  ``save`` snapshots memmap leaves lazily on the writer thread — do not
  mutate the backing store until ``wait()``.
* **Engine-streamed**: the chunked memmap copies ride the
  :mod:`repro.io` submission queue (``IOEngine`` over the ``mmap``
  adapter), so several chunks are in flight at once instead of one
  synchronous ``dst[i:j] = src[i:j]`` at a time — the same engine the
  ``tier="file"`` backing store swaps through.
* **Checksummed chunks**: every array is CRC'd per streaming chunk at save
  time and the CRCs live in the manifest (version 2); restore verifies each
  chunk, so a corrupted shard is an ``IOError`` (and ``restore_latest``
  falls back to an older checkpoint) instead of silently-wrong state.  The
  manifest itself is written temp + fsync + rename inside the staging dir,
  and both the staging dir and the checkpoint dir are fsync'd around the
  final rename — a crash at any instant leaves the previous checkpoint
  untouched and loadable.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, List, Optional, Tuple

import jax
import numpy as np

from repro.io import IOEngine, MmapFile
from repro.io.npyio import (create_npy_memmap, fsync_file,
                            load_npy_mmap, save_npy_durable)
from repro.io.checksum import CHECKSUM_ALGO, crc_bytes
from repro.core.recovery import fsync_dir


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pending: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Any, blocking: bool = True) -> str:
        """Snapshot ``state`` (any pytree of arrays) at ``step``."""
        flat, treedef = jax.tree_util.tree_flatten_with_path(state)
        # Snapshot now (device→host transfer / host copy), so training can
        # mutate buffers after.  np.asarray aliases plain ndarrays, so force
        # the copy — otherwise a host-tier backing store mutated before
        # wait() would tear the background write.  Memmap leaves are the
        # exception: they stay by reference and stream at write time instead
        # of copying v·mu into RAM (do not mutate them until wait()).
        host = [(self._key_str(path), _snapshot(leaf)) for path, leaf in flat]
        self.wait()

        def write():
            tmp = os.path.join(self.dir, f"step_{step:012d}.tmp")
            final = os.path.join(self.dir, f"step_{step:012d}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            names = []
            for i, (key, arr) in enumerate(host):
                fn = f"arr_{i:05d}.npy"
                path = os.path.join(tmp, fn)
                is_mm = isinstance(arr, np.memmap)
                if is_mm:
                    crcs = _stream_to_npy(arr, path)
                else:
                    crcs = _array_crcs(arr)
                    save_npy_durable(path, arr)
                names.append({"key": key, "file": fn,
                              "shape": list(arr.shape),
                              "dtype": str(arr.dtype),
                              "memmap": is_mm,
                              "chunk_crcs": crcs})
            manifest = {"step": step, "arrays": names,
                        "time": time.time(), "version": 2,
                        "algo": CHECKSUM_ALGO}
            # The manifest is the commit record within the staging dir:
            # write it temp + fsync + rename so even a crash *during* the
            # final directory rename below can't expose a torn manifest.
            mpath = os.path.join(tmp, "manifest.json")
            with open(mpath + ".tmp", "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            os.replace(mpath + ".tmp", mpath)
            fsync_dir(tmp)
            if os.path.exists(final):
                shutil.rmtree(tmp)       # another writer won the race
            else:
                os.replace(tmp, final)   # atomic commit
                fsync_dir(self.dir)      # persist the rename itself
            self._gc()

        if blocking:
            write()
        else:
            self._pending = threading.Thread(target=write, daemon=True)
            self._pending.start()
        return os.path.join(self.dir, f"step_{step:012d}")

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    # --------------------------------------------------------------- restore
    def restore_latest(self, like: Any = None,
                       shardings: Any = None) -> Optional[Tuple[int, Any]]:
        """Newest complete checkpoint, or None.  ``like`` supplies the pytree
        structure (its leaves are ignored); ``shardings`` optionally re-shards
        every leaf (elastic restore onto a different mesh)."""
        self.wait()
        for step in sorted(self._steps(), reverse=True):
            try:
                return step, self._load(step, like, shardings)
            except Exception:
                continue   # torn/corrupt checkpoint: fall back to older
        return None

    def restore(self, step: int, like: Any = None, shardings: Any = None):
        return self._load(step, like, shardings)

    # ---------------------------------------------------------------- intern
    def _load(self, step: int, like, shardings):
        d = os.path.join(self.dir, f"step_{step:012d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        metas = manifest["arrays"]
        # Version-2 manifests carry per-chunk CRCs; verify when the recorded
        # algorithm matches ours.  Version-1 (or cross-algo) checkpoints are
        # tolerated without verification.
        verify = manifest.get("algo") == CHECKSUM_ALGO
        if like is None:
            arrays = []
            for meta in metas:
                arr = np.load(os.path.join(d, meta["file"]))
                if list(arr.shape) != meta["shape"]:
                    raise IOError(f"shape mismatch in {meta['file']}")
                self._verify(arr, meta, verify)
                arrays.append(arr)
            return arrays
        flat, treedef = jax.tree_util.tree_flatten(like)
        if len(flat) != len(metas):
            raise IOError(
                f"checkpoint has {len(metas)} leaves, state has {len(flat)}")
        flat_sh = (treedef.flatten_up_to(shardings)
                   if shardings is not None else [None] * len(flat))
        arrays = []
        for meta, leaf, sh in zip(metas, flat, flat_sh):
            path = os.path.join(d, meta["file"])
            if isinstance(leaf, np.memmap):
                # Out-of-core leaf: stream the checkpoint into the caller's
                # backing store in bounded chunks — never on device, never
                # fully in RAM.  The leaf is filled in place.
                src = load_npy_mmap(path)
                if src.shape != leaf.shape or src.dtype != leaf.dtype:
                    raise IOError(
                        f"memmap leaf mismatch in {meta['file']}: checkpoint "
                        f"{src.shape}/{src.dtype} vs store "
                        f"{leaf.shape}/{leaf.dtype}")
                _chunked_copy(src, leaf,
                              crcs_expect=(meta.get("chunk_crcs")
                                           if verify else None),
                              label=meta["file"])
                leaf.flush()
                arrays.append(leaf)
                continue
            arr = np.load(path)
            if list(arr.shape) != meta["shape"]:
                raise IOError(f"shape mismatch in {meta['file']}")
            self._verify(arr, meta, verify)
            arrays.append(jax.device_put(arr) if sh is None
                          else jax.device_put(arr, sh))
        return jax.tree_util.tree_unflatten(treedef, arrays)

    @staticmethod
    def _verify(arr: np.ndarray, meta: dict, verify: bool) -> None:
        crcs = meta.get("chunk_crcs")
        if not verify or crcs is None:
            return
        got = _array_crcs(arr)
        if got != crcs:
            ci = next((i for i, (a, b) in enumerate(zip(got, crcs))
                       if a != b), min(len(got), len(crcs)))
            raise _crc_mismatch(meta["file"], ci)

    def _steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return out

    def _gc(self) -> None:
        steps = sorted(self._steps())
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:012d}"),
                          ignore_errors=True)

    @staticmethod
    def _key_str(path) -> str:
        return jax.tree_util.keystr(path)


def _snapshot(leaf):
    if isinstance(leaf, np.memmap):
        return leaf
    arr = np.asarray(leaf)
    return arr.copy() if arr is leaf else arr


_STREAM_CHUNK_BYTES = 64 << 20   # bound on resident bytes while streaming
_STREAM_QUEUE_DEPTH = 4          # chunks in flight on the engine


def _chunk_rows(shape, itemsize: int) -> Tuple[int, int]:
    """(row bytes, rows per streaming chunk) for an array of ``shape``."""
    row = max(1, int(np.prod(shape[1:], dtype=np.int64))) * itemsize
    return row, max(1, _STREAM_CHUNK_BYTES // (row * _STREAM_QUEUE_DEPTH))


def _chunk_crc(chunk: np.ndarray) -> int:
    return crc_bytes(np.ascontiguousarray(chunk).reshape(-1).view(np.uint8))


def _array_crcs(arr: np.ndarray) -> List[int]:
    """Per-chunk CRCs of ``arr`` using the streaming chunk geometry (so the
    save and restore sides agree without storing the chunk size)."""
    a = np.asarray(arr)
    if a.ndim == 0:
        return [crc_bytes(a.tobytes())]
    _, step = _chunk_rows(a.shape, a.itemsize)
    return [_chunk_crc(a[i:i + step]) for i in range(0, a.shape[0], step)]


def _crc_mismatch(path: str, ci: int) -> IOError:
    return IOError(
        f"checksum mismatch in {path} (chunk {ci}): the checkpoint shard is "
        f"torn or corrupt; restore_latest will fall back to an older step")


def _chunked_copy(src, dst, crcs_out: Optional[List[int]] = None,
                  crcs_expect: Optional[List[int]] = None,
                  label: str = "<array>") -> None:
    """Copy array ``src`` into ``dst`` in ≤ 64 MiB chunks along axis 0
    (whole-array for 0-d), keeping the resident footprint bounded.

    When ``dst`` is an ``np.memmap`` the chunks are submitted through a
    :class:`repro.io.IOEngine` over the mmap adapter, so up to
    ``_STREAM_QUEUE_DEPTH`` chunk copies overlap instead of serialising on
    one thread.  The resident bound becomes chunk × queue-depth.

    ``crcs_out`` (save path) collects a CRC per chunk, computed in the
    submitting thread — the manifest records what was *sent*, so a write the
    OS tears is detectable.  ``crcs_expect`` (restore path) verifies each
    chunk of ``src`` before it is copied, raising :class:`IOError` on
    mismatch — corrupt checkpoint bytes are rejected instead of streamed
    into the live store.
    """
    checking = crcs_out is not None or crcs_expect is not None
    if src.ndim == 0:
        if checking:
            crc = crc_bytes(np.asarray(src).tobytes())
            if crcs_out is not None:
                crcs_out.append(crc)
            if crcs_expect is not None and crc != crcs_expect[0]:
                raise _crc_mismatch(label, 0)
        dst[...] = src
        return
    row, step = _chunk_rows(src.shape, src.itemsize)

    def check(chunk, ci):
        if not checking:
            return chunk
        chunk = np.ascontiguousarray(chunk)
        crc = _chunk_crc(chunk)
        if crcs_out is not None:
            crcs_out.append(crc)
        if crcs_expect is not None and (
                ci >= len(crcs_expect) or crc != crcs_expect[ci]):
            raise _crc_mismatch(label, ci)
        return chunk

    if (not isinstance(dst, np.memmap) or not dst.flags.c_contiguous
            or not src.flags.c_contiguous):
        # Strided/F-order leaves: the engine needs C-contiguous chunk
        # buffers (memoryview cast) and a flat byte view of dst — numpy
        # assignment handles these layouts instead.
        for ci, i in enumerate(range(0, src.shape[0], step)):
            dst[i:i + step] = check(src[i:i + step], ci)
        return
    flat = dst.reshape(-1).view(np.uint8)
    engine = IOEngine(MmapFile(mm=flat), queue_depth=_STREAM_QUEUE_DEPTH)
    try:
        for ci, i in enumerate(range(0, src.shape[0], step)):
            engine.submit_write(i * row, check(src[i:i + step], ci),
                                auto_reap=True)
        engine.drain()
    finally:
        engine.close()


def _stream_to_npy(arr: np.memmap, path: str) -> List[int]:
    """Write a memmap to ``.npy`` by chunked copy (no full-RAM staging),
    fsync'd like the regular save path.  Returns the per-chunk CRCs."""
    crcs: List[int] = []
    out = create_npy_memmap(path, arr.dtype, arr.shape)
    try:
        _chunked_copy(arr, out, crcs_out=crcs)
        out.flush()
    finally:
        del out
    fsync_file(path)
    return crcs
