"""Virtual-processor contexts: allocator, layout, store and views.

The thesis stores each virtual processor's memory (its *context*, size μ) in
external memory and swaps it into one of ``k`` partitions.  PEMS2 replaces the
bump allocator of PEMS1 with offset/size records and a free list so memory can
be freed and reused, and so swapping touches only *live* bytes (§6.6).

JAX arrays have static shapes, so allocation happens at trace time: a
:class:`Allocator` hands out word offsets inside the context, and a
:class:`ContextLayout` maps field names to ``(offset, shape, dtype)``.  The
whole population of contexts is a single ``[v, mu_words]`` array (the
:class:`ContextStore`) that can be sharded over a mesh axis — that array *is*
the external memory.  4-byte word granularity keeps bitcasts exact for
float32/int32/uint32 payloads (the BSP applications' element types).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

WORD = 4  # bytes per store word

_SUPPORTED = {
    jnp.dtype("float32"), jnp.dtype("int32"), jnp.dtype("uint32"),
}


# --------------------------------------------------------------------------- #
# Allocator (§6.6)                                                             #
# --------------------------------------------------------------------------- #

@dataclasses.dataclass
class _Chunk:
    offset: int
    size: int


class Allocator:
    """First-fit free-list allocator with merge-on-free (thesis §6.6).

    Offsets/sizes are in words.  ``live_words`` lets the swap engine move only
    allocated bytes, reproducing the PEMS2 "swap only allocated regions"
    optimisation.
    """

    def __init__(self, capacity: int):
        self.capacity = int(capacity)
        self._free: List[_Chunk] = [_Chunk(0, self.capacity)]
        self._allocated: Dict[int, int] = {}  # offset -> size

    def alloc(self, size: int) -> int:
        size = int(size)
        if size <= 0:
            raise ValueError(f"allocation size must be positive, got {size}")
        # First fit, scanning from the lowest address (§6.6).
        for i, chunk in enumerate(self._free):
            if chunk.size >= size:
                offset = chunk.offset
                if chunk.size == size:
                    self._free.pop(i)
                else:
                    chunk.offset += size
                    chunk.size -= size
                self._allocated[offset] = size
                return offset
        raise MemoryError(
            f"context exhausted: requested {size} words, "
            f"free={self.free_words} of {self.capacity}"
        )

    def free(self, offset: int) -> None:
        size = self._allocated.pop(offset, None)
        if size is None:
            raise ValueError(f"free of unallocated offset {offset}")
        # Insert sorted and merge with adjacent free chunks.
        new = _Chunk(offset, size)
        idx = 0
        while idx < len(self._free) and self._free[idx].offset < offset:
            idx += 1
        self._free.insert(idx, new)
        self._merge(idx)
        if idx > 0:
            self._merge(idx - 1)

    def _merge(self, i: int) -> None:
        while i + 1 < len(self._free):
            a, b = self._free[i], self._free[i + 1]
            if a.offset + a.size == b.offset:
                a.size += b.size
                self._free.pop(i + 1)
            else:
                break

    @property
    def live_words(self) -> int:
        return sum(self._allocated.values())

    @property
    def free_words(self) -> int:
        return self.capacity - self.live_words

    @property
    def n_free_chunks(self) -> int:
        """Fragmentation indicator."""
        return len(self._free)


# --------------------------------------------------------------------------- #
# Layout                                                                       #
# --------------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class Field:
    name: str
    shape: Tuple[int, ...]
    dtype: jnp.dtype

    @property
    def words(self) -> int:
        return int(np.prod(self.shape, dtype=np.int64)) if self.shape else 1


class ContextLayout:
    """Named fields inside a context, placed by the allocator."""

    def __init__(self, capacity_words: Optional[int] = None):
        self._fields: Dict[str, Tuple[int, Field]] = {}
        self._capacity = capacity_words
        self._alloc: Optional[Allocator] = (
            Allocator(capacity_words) if capacity_words else None
        )
        self._next = 0  # bump fallback when capacity unknown

    def add(self, name: str, shape: Sequence[int], dtype=jnp.float32) -> "ContextLayout":
        dtype = jnp.dtype(dtype)
        if dtype not in _SUPPORTED:
            raise TypeError(f"context fields must be 4-byte dtypes, got {dtype}")
        if name in self._fields:
            raise ValueError(f"duplicate field {name!r}")
        f = Field(name, tuple(int(s) for s in shape), dtype)
        if f.words == 0:
            # A zero-dim shape would make field_words() == 0 while the
            # allocator hands out ≥ 1 word, desynchronising the ledger's byte
            # counts from Allocator.live_words.  Reject it outright.
            raise ValueError(
                f"field {name!r} has zero size (shape {f.shape}); "
                "context fields must occupy at least one word"
            )
        if self._alloc is not None:
            off = self._alloc.alloc(f.words)
        else:
            off = self._next
            self._next += f.words
        self._fields[name] = (off, f)
        return self

    def drop(self, name: str) -> "ContextLayout":
        """Free a field (its words become reusable — §6.6)."""
        off, _ = self._fields.pop(name)
        if self._alloc is not None:
            self._alloc.free(off)
        return self

    def offset(self, name: str) -> int:
        return self._fields[name][0]

    def field(self, name: str) -> Field:
        return self._fields[name][1]

    def field_words(self, name: str) -> int:
        return self._fields[name][1].words

    def field_bytes(self, name: str) -> int:
        return self.field_words(name) * WORD

    @property
    def names(self) -> List[str]:
        return list(self._fields)

    @property
    def words(self) -> int:
        """Context size in words (μ / 4).  With an allocator this is the fixed
        capacity; otherwise the high-water mark of the bump pointer."""
        if self._capacity is not None:
            return self._capacity
        return max(self._next, 1)

    @property
    def live_words(self) -> int:
        if self._alloc is not None:
            return self._alloc.live_words
        return sum(f.words for _, f in self._fields.values())

    @property
    def mu_bytes(self) -> int:
        """μ: the context size in bytes."""
        return self.words * WORD

    @property
    def live_bytes(self) -> int:
        return self.live_words * WORD

    def live_word_index(self) -> Optional[np.ndarray]:
        """Sorted word offsets of every *live* (field-allocated) word, or
        ``None`` when the whole context is live — the common bump-layout
        case, where callers can skip the gather/scatter entirely.

        This is what lets the backing-tier swap engine move only allocated
        bytes (PEMS2 §6.6): a layout with freed holes swaps ``live_words``
        words per context, not ``words``.
        """
        if self.live_words == self.words:
            return None
        return field_word_index(self, self.names)


def field_word_index(layout_: ContextLayout,
                     names: Sequence[str]) -> np.ndarray:
    """Union of the named fields' word ranges, sorted — the monotone
    gather/scatter index for sliced and live-word swaps."""
    ranges = [
        np.arange(layout_.offset(n), layout_.offset(n) + layout_.field_words(n))
        for n in names
    ]
    return np.unique(np.concatenate(ranges)) if ranges else np.arange(0)


def layout(fields: Iterable[Tuple[str, Sequence[int], object]],
           capacity_words: Optional[int] = None) -> ContextLayout:
    lo = ContextLayout(capacity_words)
    for name, shape, dtype in fields:
        lo.add(name, shape, dtype)
    return lo


# --------------------------------------------------------------------------- #
# Context view                                                                 #
# --------------------------------------------------------------------------- #

def _to_words(x: jnp.ndarray) -> jnp.ndarray:
    if x.dtype == jnp.uint32:
        return x
    return jax.lax.bitcast_convert_type(x, jnp.uint32)


def _from_words(w: jnp.ndarray, dtype) -> jnp.ndarray:
    dtype = jnp.dtype(dtype)
    if dtype == jnp.uint32:
        return w
    return jax.lax.bitcast_convert_type(w, dtype)


class Ctx:
    """A single swapped-in context: a ``[words]`` uint32 vector with typed
    field accessors.  Functional: ``set`` returns a new view."""

    def __init__(self, layout: ContextLayout, words: jnp.ndarray):
        self.layout = layout
        self.words = words

    def get(self, name: str) -> jnp.ndarray:
        off = self.layout.offset(name)
        f = self.layout.field(name)
        flat = jax.lax.slice_in_dim(self.words, off, off + f.words, axis=0)
        return _from_words(flat, f.dtype).reshape(f.shape)

    def set(self, name: str, value: jnp.ndarray) -> "Ctx":
        off = self.layout.offset(name)
        f = self.layout.field(name)
        value = jnp.asarray(value, f.dtype).reshape((f.words,))
        new = jax.lax.dynamic_update_slice_in_dim(
            self.words, _to_words(value), off, axis=0
        )
        return Ctx(self.layout, new)

    def update(self, **kv) -> "Ctx":
        c = self
        for k, v in kv.items():
            c = c.set(k, v)
        return c


# --------------------------------------------------------------------------- #
# Store                                                                        #
# --------------------------------------------------------------------------- #

@jax.tree_util.register_pytree_node_class
class ContextStore:
    """All ``v`` contexts: the external memory.  ``data`` is ``[v, words]``
    uint32, shardable on axis 0 over the mesh's virtual-processor axis."""

    def __init__(self, layout: ContextLayout, data: jnp.ndarray):
        self.layout = layout
        self.data = data

    # pytree plumbing -------------------------------------------------------
    def tree_flatten(self):
        return (self.data,), self.layout

    @classmethod
    def tree_unflatten(cls, layout, children):
        return cls(layout, children[0])

    # convenience -----------------------------------------------------------
    @property
    def v(self) -> int:
        return self.data.shape[0]

    @property
    def mu_bytes(self) -> int:
        return self.layout.mu_bytes

    def field(self, name: str) -> jnp.ndarray:
        """Gather a field across all contexts → ``[v, *shape]`` (host debugging
        / result extraction; not part of the simulated I/O)."""
        off = self.layout.offset(name)
        f = self.layout.field(name)
        flat = self.data[:, off:off + f.words]
        return _from_words(flat, f.dtype).reshape((self.v,) + f.shape)

    def with_field(self, name: str, value: jnp.ndarray) -> "ContextStore":
        off = self.layout.offset(name)
        f = self.layout.field(name)
        value = jnp.asarray(value, f.dtype).reshape((self.v, f.words))
        data = jax.lax.dynamic_update_slice(
            self.data, _to_words(value), (0, off)
        )
        return ContextStore(self.layout, data)

    # word-level access --------------------------------------------------- #
    # The fused Alltoallv path moves raw context words (the on-disk byte
    # ranges), skipping the typed gather→bitcast→reshape round-trip: a field
    # is just a contiguous word range of every context row.

    def field_words_view(self, name: str) -> jnp.ndarray:
        """Raw ``[v, field_words]`` uint32 view of a field's word range
        across all contexts — no bitcast, no reshape to the field shape."""
        off = self.layout.offset(name)
        n = self.layout.field_words(name)
        return jax.lax.slice(self.data, (0, off), (self.v, off + n))

    def with_field_words(self, name: str, words: jnp.ndarray) -> "ContextStore":
        """Write a field's raw word range from a ``[v, field_words]`` uint32
        array (inverse of :meth:`field_words_view`).

        The row is rebuilt with a concatenate rather than a
        dynamic-update-slice: XLA fuses the incoming value's producer (e.g.
        the delivery transpose) straight into the concatenate's output loop,
        where a dynamic-update-slice materialises the operand first — on CPU
        this is a consistent ~1.5× win for Alltoallv-sized writes.
        """
        off = self.layout.offset(name)
        n = self.layout.field_words(name)
        if words.dtype != jnp.uint32:
            raise TypeError(f"word-level writes must be uint32, got {words.dtype}")
        words = words.reshape((self.v, n))
        left = jax.lax.slice(self.data, (0, 0), (self.v, off))
        right = jax.lax.slice(
            self.data, (0, off + n), (self.v, self.data.shape[1])
        )
        data = jnp.concatenate([left, words, right], axis=1)
        return ContextStore(self.layout, data)


def init_store(layout_: ContextLayout, v: int,
               init_fn: Optional[Callable[[jnp.ndarray], Dict[str, jnp.ndarray]]] = None
               ) -> ContextStore:
    """Create a store; ``init_fn(rho) -> {field: value}`` runs vmapped over the
    virtual-processor IDs to populate initial contexts."""
    data = jnp.zeros((v, layout_.words), jnp.uint32)
    store = ContextStore(layout_, data)
    if init_fn is not None:
        def one(rho):
            ctx = Ctx(layout_, jnp.zeros((layout_.words,), jnp.uint32))
            vals = init_fn(rho)
            for name, val in vals.items():
                ctx = ctx.set(name, val)
            return ctx.words
        data = jax.vmap(one)(jnp.arange(v, dtype=jnp.int32))
        store = ContextStore(layout_, data)
    return store
