"""Durable superstep cursor: crash recovery for the out-of-core path.

A long out-of-core run is a sequence of named stages (supersteps and
collectives) mutating one backing file.  To survive ``kill -9`` the runner
needs exactly two tiny pieces of durable state:

* **the cursor** — which stage last *committed* (its writes flushed to the
  backing file) and which stage, if any, was *in progress* when the process
  died.  :class:`SuperstepCursor` stores this as an atomically-replaced,
  fsynced JSON file: a crash mid-update leaves the previous cursor intact,
  so the resume decision is always made from consistent state.
* **a pre-stage snapshot** of any field a stage both reads and writes
  (taken by the runner, e.g. :func:`repro.pems_apps.psrs.psrs_run_recoverable`)
  — rerunning such a stage from a torn row would compute garbage-from-
  garbage, so the resume restores the snapshot first and reruns the stage
  from its true input.  Stages whose read and write sets are disjoint rerun
  idempotently with no snapshot.

The protocol per stage ``i``::

    snapshot read∩write fields (if any)      # atomic npz
    cursor.mark_in_progress(i)               # durable
    run the stage
    store.flush()                            # backing + sidecar durable
    cursor.mark_completed(i)                 # durable

On resume: stages ``<= completed`` are skipped; if ``in_progress`` is set,
the backing's checksums are recomputed (the sidecar may record intended CRCs
for writes the crash tore — those rows are about to be regenerated), the
snapshot is restored, and the stage reruns — bit-identically, because every
input byte is either from a committed flush or from the snapshot.
"""

from __future__ import annotations

import json
import os
from typing import Optional

from repro.obs import NOOP

__all__ = ["atomic_replace_file", "atomic_write_json", "fsync_dir",
           "SuperstepCursor"]


def fsync_dir(path: str) -> None:
    """fsync a directory so a just-renamed entry survives power loss."""
    try:
        # Directory handle for fsync only — no data bytes move through it,
        # so there is nothing for the IOLedger to see.
        fd = os.open(path, os.O_RDONLY)  # pems-lint: disable=block-api-only
    except OSError:
        return                     # e.g. platforms without dir-open support
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def atomic_replace_file(path: str, write_fn, binary: bool = False,
                        durable: bool = True) -> None:
    """Atomically replace ``path`` with whatever ``write_fn(f)`` writes.

    The durability protocol in one place: ``write_fn`` writes into a
    ``path + ".tmp"`` temp file, which is flushed + fsynced, atomically
    renamed over ``path``, and the directory fsynced so the rename itself
    survives power loss.  Readers see either the old contents or the new —
    never a torn mix.  ``durable=False`` skips both fsyncs for advisory
    state where the rename's atomicity alone is enough; ``binary=True``
    opens the temp file in ``"wb"`` mode (e.g. npz stage snapshots).
    """
    tmp = path + ".tmp"
    # Audited raw open: this *is* the durable-state write path (cursor
    # JSON, stage snapshots) — control state, not ledger-visible backing
    # data, which must keep flowing through the block API.
    with open(tmp, "wb" if binary else "w") as f:  # pems-lint: disable=block-api-only
        write_fn(f)
        if durable:
            f.flush()
            os.fsync(f.fileno())
    os.replace(tmp, path)
    if durable:
        fsync_dir(os.path.dirname(path) or ".")


def atomic_write_json(path: str, obj, durable: bool = True) -> None:
    """Write ``obj`` as JSON to ``path`` via :func:`atomic_replace_file`
    (temp + fsync + rename + directory fsync when ``durable``)."""
    atomic_replace_file(path, lambda f: json.dump(obj, f), durable=durable)


class SuperstepCursor:
    """Tiny durable record of stage progress for one recoverable run.

    State: ``{"completed": i, "in_progress": j|None, "stage": name,
    "round": r}`` — ``completed`` is the index of the last stage whose
    writes are flushed, ``in_progress`` the stage that was running (None
    between stages), ``round`` an advisory executor-round note within the
    in-progress stage.

    Under the sharded backing (``P > 1``) a recoverable run keeps **one
    cursor per process** (see :meth:`path_for`): process p's cursor commits
    when *its shard's* writes are flushed, so a single-disk failure leaves
    the other processes' cursors at the completed stage and only the failed
    process re-runs (``procs=[p]``).
    """

    # repro.obs tracing (attached post-construction by the runner, like the
    # engine's): mark_in_progress opens a span on the recovery lane that
    # mark_completed closes, so the trace shows each stage's durable
    # in-progress window — exactly what a resume decision is made from.
    tracer = NOOP
    trace_tid = "recovery"

    def __init__(self, path: str):
        self.path = path
        self._cur = self._load()

    @staticmethod
    def path_for(state_dir: str, proc: int = 0, nprocs: int = 1) -> str:
        """The cursor file for process ``proc`` of ``nprocs`` under
        ``state_dir`` — the bare legacy name at ``nprocs == 1`` so existing
        single-process state dirs resume unchanged."""
        if nprocs == 1:
            return os.path.join(state_dir, "cursor.json")
        return os.path.join(state_dir, f"cursor.p{proc}.json")

    def _load(self) -> Optional[dict]:
        try:
            with open(self.path) as f:
                return json.load(f)
        except (OSError, ValueError):
            return None

    # ----------------------------------------------------------------- state
    def state(self) -> Optional[dict]:
        """The persisted state, or None for a fresh run."""
        return None if self._cur is None else dict(self._cur)

    @property
    def completed(self) -> int:
        return -1 if self._cur is None else int(self._cur.get("completed", -1))

    @property
    def in_progress(self) -> Optional[int]:
        return None if self._cur is None else self._cur.get("in_progress")

    # ------------------------------------------------------------- transitions
    def mark_in_progress(self, stage: int, name: Optional[str] = None) -> None:
        self._cur = {"completed": self.completed, "in_progress": stage,
                     "stage": name, "round": None}
        atomic_write_json(self.path, self._cur, durable=True)
        # Audited cross-call pair: the matching end() is in mark_completed —
        # the in-progress window *is* the span, and a crash inside it is
        # closed at export by the balance sanitizer.
        # pems-lint: disable=trace-balance
        self.tracer.begin(f"in_progress:{name or stage}", tid=self.trace_tid,
                          cat="recovery", stage=stage)

    def mark_completed(self, stage: int, name: Optional[str] = None) -> None:
        self._cur = {"completed": stage, "in_progress": None,
                     "stage": name, "round": None}
        atomic_write_json(self.path, self._cur, durable=True)
        self.tracer.end(f"in_progress:{name or stage}", tid=self.trace_tid)

    def note_round(self, r: int) -> None:
        """Advisory executor-round progress (atomic but not fsynced — a
        resume restarts the whole in-progress stage regardless)."""
        if self._cur is None:
            self._cur = {"completed": -1, "in_progress": None,
                         "stage": None, "round": None}
        self._cur["round"] = r
        atomic_write_json(self.path, self._cur, durable=False)

    def clear(self) -> None:
        self._cur = None
        try:
            os.unlink(self.path)
        except OSError:
            pass
