"""Closed-form I/O / time models transcribed from the thesis.

Each function is a direct transcription of a lemma/theorem so the executable
simulation (``repro.core``) can be validated *exactly* against the paper:

* Lemma 2.2.1 / Thm 2.2.2 / Thm 2.2.3  — PEMS1 single-processor Alltoallv
* Lemma 7.1.3 / Cor 7.1.4 / Thm 7.1.6  — PEMS2 EM-Alltoallv-Seq
* Lemma 7.1.8 / Thm 7.1.10             — PEMS2 EM-Alltoallv-Par
* Lemma 7.2.1 / Thm 7.2.3              — EM-Bcast
* Lemma 7.3.1 / Thm 7.3.3              — EM-Gather
* Lemma 7.4.2 / Thm 7.4.4              — EM-Reduce
* §6.3 / Fig 6.2                       — disk-space requirements

All byte quantities share one unit (bytes); time models are parameterised by
the EM-BSP coefficients (Appendix B.4): S, G (seconds per block of size B),
g, l (BSP* network), L (virtual superstep overhead).

Known thesis inconsistency: Lemma 7.1.8 with
``P = 1`` does **not** reduce to Lemma 7.1.3 because the parallel analysis
counts all ``v²/P`` network-received deliveries even when every destination is
local.  The event-level simulation in :mod:`repro.core.collectives` resolves
the local/remote split exactly; tests check it against Lemma 7.1.3 at ``P = 1``
and against :func:`pems2_alltoallv_par_io_exact` for ``P > 1``.
"""

from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class MachineModel:
    """EM-BSP system parameters (thesis Appendix B.4)."""

    B: int = 4096            # disk block size, bytes
    D: int = 1               # disks per real processor
    S: float = 1.0           # time per swapped block
    G: float = 1.0           # time per delivered block
    g: float = 0.0           # time per network packet of size b
    b: int = 4096            # minimum network message for rated throughput
    l: float = 0.0           # network superstep overhead
    L: float = 0.0           # virtual superstep overhead


# --------------------------------------------------------------------------- #
# PEMS1 Alltoallv (baseline), thesis §2.2                                      #
# --------------------------------------------------------------------------- #

def pems1_alltoallv_io(v: int, mu: int, omega: int) -> int:
    """Lemma 2.2.1: total I/O volume of SIMPLE-ALLTOALLV-SEQ."""
    return 4 * v * mu + 2 * v * v * omega


def pems1_alltoallv_time(v: int, mu: int, omega: int, m: MachineModel) -> float:
    """Thm 2.2.2: S·4vμ/B + G·2v²⌈ω⌉/B + 2L."""
    om = round_up(omega, m.B)
    return m.S * 4 * v * mu / m.B + m.G * 2 * v * v * om / m.B + 2 * m.L


def pems1_alltoallv_disk(v: int, P: int, mu: int, omega: int) -> int:
    """Thm 2.2.3 / §6.3: per-real-processor disk: vμ/P contexts + v²ω indirect
    area sized for all incoming messages (the indirect area scales with v)."""
    return v * mu // P + v * v * omega


# --------------------------------------------------------------------------- #
# PEMS2 EM-Alltoallv, thesis §7.1                                              #
# --------------------------------------------------------------------------- #

def alltoallv_delta_seq(v: int, k: int) -> int:
    """δ of Lemma 7.1.3: messages deliverable directly, ID-ordered rounds."""
    assert v % k == 0
    return (v * v + v * k) // 2


def pems2_alltoallv_seq_io(v: int, k: int, mu: int, omega: int, B: int) -> int:
    """Lemma 7.1.3: vμ + ((v²−vk)/2)·ω + 2v²B."""
    return v * mu + ((v * v - v * k) * omega) // 2 + 2 * v * v * B


def pems2_alltoallv_seq_improvement(
    v: int, k: int, mu: int, omega: int, B: int
) -> int:
    """Cor 7.1.4: 2vμ + ((3v²+vk)/2)·ω − 2v²B less I/O than PEMS1."""
    return 2 * v * mu + ((3 * v * v + v * k) * omega) // 2 - 2 * v * v * B


def pems2_alltoallv_seq_buffer(v: int, P: int, B: int) -> int:
    """Lemma 7.1.5: boundary-block cache ≤ 2v²B/P."""
    return 2 * v * v * B // P


def pems2_alltoallv_seq_time(
    v: int, k: int, mu: int, omega: int, m: MachineModel
) -> float:
    """Thm 7.1.6: S·vμ/BD + G·(v²−vk)ω/2BD + G·2v²/D + L."""
    return (
        m.S * v * mu / (m.B * m.D)
        + m.G * (v * v - v * k) * omega / (2 * m.B * m.D)
        + m.G * 2 * v * v / m.D
        + m.L
    )


def pems2_alltoallv_par_io_thesis(
    v: int, P: int, k: int, mu: int, omega: int, B: int
) -> float:
    """Lemma 7.1.8 as printed: vμ/P + (v²/P + 3v²/2P² − kv/2P − v²)ω + 2v²B."""
    return (
        v * mu / P
        + (v * v / P + 3 * v * v / (2 * P * P) - k * v / (2 * P) - v * v) * omega
        + 2 * v * v * B
    )


def pems2_alltoallv_par_io_exact(
    v: int, P: int, k: int, mu: int, omega: int, B: int
) -> int:
    """Event-exact global I/O of EM-Alltoallv-Par with the local/remote split.

    Per real processor, m = v/P local VPs:
      * swap out all contexts minus the v receive slots:    m·(μ − v·ω)
      * local deliveries: δ direct (ω) + (m² − δ) late (2ω) with
        δ = (m² + mk)/2  (ID-ordered rounds of k, Lemma 7.1.3 structure)
      * network-received messages delivered to disk:        m·(v − m)·ω
      * boundary-block flush (2v blocks per local VP):      2·m·v·B
    """
    m = v // P
    delta = (m * m + m * k) // 2
    per_proc = (
        m * (mu - v * omega)
        + delta * omega
        + 2 * (m * m - delta) * omega
        + m * (v - m) * omega
        + 2 * m * v * B
    )
    return per_proc * P


def pems2_alltoallv_par_buffer(v: int, P: int, k: int, alpha: int, omega: int,
                               B: int) -> int:
    """Lemma 7.1.9: 2v²B/P + αkω."""
    return 2 * v * v * B // P + alpha * k * omega


def pems2_alltoallv_par_comm_time(
    v: int, P: int, k: int, alpha: int, omega: int, m: MachineModel
) -> float:
    """Lemma 7.1.7: g·αkω/b + l·v²/(Pkα)."""
    return m.g * alpha * k * omega / m.b + m.l * v * v / (P * k * alpha)


def pems2_alltoallv_par_network_rounds(v: int, P: int, k: int,
                                       alpha) -> int:
    """Bulk all-to-all launches of the network phase.  Unchunked
    (``alpha=None``): a single launch.  α-chunked (Alg 7.1.3): the m = v/P
    local contexts proceed in source rounds of k, each shipping its
    destinations in ⌈m/α⌉ α-chunks — one launch per (round, chunk), moving
    ≤ α·k·ω words per (source, destination) process pair (Lemma 7.1.9's
    buffer bound).  Lemma 7.1.7's ``l`` term counts v²/(Pkα) = P· the
    chunked count in *point-to-point* rounds; a bulk all-to-all serves all
    P destinations at once."""
    if alpha is None:
        return 1
    m = v // P
    return (m // k) * -(-m // alpha)


def pems2_disk_space(v: int, P: int, mu: int) -> int:
    """§6.3: PEMS2 needs exactly vμ/P per real processor (no indirect area)."""
    return v * mu // P


# --------------------------------------------------------------------------- #
# Rooted collectives, thesis §7.2–7.4                                          #
# --------------------------------------------------------------------------- #

def em_bcast_io(v: int, P: int, k: int, mu: int, omega: int) -> int:
    """Lemma 7.2.1 worst case: swap 2vμ/(Pk) (root-partition sharers swap out
    and back in) + every VP delivers the ω payload to its context."""
    return 2 * v * mu // (P * k) + v * omega


def em_bcast_time(v: int, P: int, k: int, mu: int, omega: int,
                  m: MachineModel) -> float:
    """Thm 7.2.3: S·2vμ/PkB + G·vω/PDB + g·ω/b + l + L."""
    return (
        m.S * 2 * v * mu / (P * k * m.B)
        + m.G * v * omega / (P * m.D * m.B)
        + m.g * omega / m.b
        + m.l
        + m.L
    )


def em_gather_io(mu: int, omega: int) -> int:
    """Lemma 7.3.1 worst case: the root may swap out (μ) and deliver vω... the
    thesis bound is μ + ω (root swap + result write at block granularity)."""
    return mu + omega


def em_gather_time(v: int, P: int, mu: int, omega: int, m: MachineModel) -> float:
    """Thm 7.3.3: S·(μ+ω)/BD + g·vω/(Pb) + l·v/P + L."""
    return (
        m.S * (mu + omega) / (m.B * m.D)
        + m.g * v * omega / (P * m.b)
        + m.l * v / P
        + m.L
    )


def em_reduce_io(n: int, omega: int) -> int:
    """Lemma 7.4.2: the root delivers the n·ω result to its context."""
    return n * omega


def em_reduce_time(v: int, P: int, k: int, n: int, omega: int,
                   m: MachineModel) -> float:
    """Thm 7.4.4: G·nω/B + g·nω·lgP/b + l·lgP + n·lgP + nv/(Pk) + nk + L."""
    lgP = math.log2(P) if P > 1 else 0.0
    return (
        m.G * n * omega / m.B
        + m.g * n * omega * lgP / m.b
        + m.l * lgP
        + n * lgP
        + n * v / (P * k)
        + n * k
        + m.L
    )


# --------------------------------------------------------------------------- #
# Fig 6.2 — disk-space table                                                   #
# --------------------------------------------------------------------------- #

def disk_space_table(v_per_p: int, mu: int, procs: tuple = (1, 2, 4, 8, 16)):
    """Reproduces Fig 6.2 rows: (P, v, required, PEMS1/proc, PEMS1 total,
    PEMS2/proc, PEMS2 total), all in bytes."""
    rows = []
    for P in procs:
        v = v_per_p * P
        required = v * mu
        pems1_per = v_per_p * mu + v * mu  # contexts + indirect area (scales v)
        pems2_per = v_per_p * mu
        rows.append((P, v, required, pems1_per, pems1_per * P, pems2_per,
                     pems2_per * P))
    return rows


def round_up(x: int, b: int) -> int:
    return -(-x // b) * b


def round_down(x: int, b: int) -> int:
    return (x // b) * b
