"""EM collective communication (thesis §2.2, §6.2, §7).

Message model: a sending context holds a field of shape ``[v, ω]`` (one padded
message per destination, ω the thesis' per-message bound) plus a ``[v]`` count
field; after Alltoallv the receiving context's ``[v, ω]`` field holds message
``recv[s] = send_of_s[ρ]``.  The destination slot offsets are static layout
offsets — the thesis' shared offset table ``T`` (§6.2) made trace-time.

Two Alltoallv implementations are provided:

* ``mode="direct"``   — PEMS2 (Alg 7.1.1/7.1.2): messages move straight from
  source contexts to destination contexts; with ``P > 1`` the network phase is
  α-chunked (Alg 7.1.3) so the communication buffer stays ≤ α·k·ω per
  destination process.
* ``mode="indirect"`` — PEMS1 baseline (Alg 2.2.1): messages are staged
  through a separate "indirect area" (an extra ``[v, v, ω]`` buffer behind an
  optimization barrier so XLA cannot fuse the copy away), costing the extra
  write+read the thesis eliminates.

Direct mode with ``P == 1`` routes through the fused *word-level* delivery
path by default (``use_kernel=True``): the send field's raw word range is
sliced straight out of the ``[v, words]`` context store
(:meth:`ContextStore.field_words_view`), handed to the Pallas direct-delivery
kernel (:mod:`repro.kernels.alltoallv_deliver` — compiled on TPU, vectorised
fallback elsewhere, interpret mode for tests), and the delivered ``[v(dst),
v(src), ω]`` block is written back into the recv word range
(:meth:`ContextStore.with_field_words`; on CPU, cache-sized ω instead takes
a row-at-a-time in-place loop, ``_deliver_rows_inplace``).  This collapses
the seed's dense gather→bitcast→reshape→transpose→scatter round-trip into
slice → deliver → store-row rebuild, fuses the counts transpose into the
same kernel call, and — when the caller passes ``fill`` — also fuses the
receiver's boundary mask (lanes past ``counts[s, d]`` arrive as ``fill``,
the thesis' boundary-block fix-up), so applications like PSRS no longer
re-mask downstream.  ``use_kernel=False`` keeps the seed's dense-transpose
path; both are bit-identical (and ≈1.6–2.8× apart in wall time on CPU at
v=16, ω ≥ 256 — see ``benchmarks/bench_alltoallv.py``).

With ``P > 1`` the same word-level route runs per mesh process
(``_alltoallv_fused_mesh``): the send field's raw word range crosses the
network directly and the (src_proc, dst_proc)-tiled kernel delivers it
into the destination rows, boundary mask and counts transpose fused — the
dense ``[m, v, ω]`` per-process transposed staging of ``_global_transpose``
never materializes.  Unchunked (``alpha=None``) this is a single
``lax.all_to_all`` feeding one concat row rebuild; with ``alpha`` set the
network phase is α-chunked (Alg 7.1.3) into one ``[k, P, α, ω]`` buffer per
(source round, destination chunk) — ≤ α·k·ω words per process pair, the
Lemma 7.1.9 bound — delivered in place chunk by chunk.  ``use_kernel=False``
keeps the dense route for equivalence testing.

The I/O ledger is updated with *event-level* counts that tests validate
against the closed forms in :mod:`repro.core.analysis`; the delivery
implementation (kernel vs dense, masked vs not) never changes the event
counts — they model the simulated external-memory traffic, not the host
execution strategy.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as _np
from jax import lax
from jax.sharding import PartitionSpec as P

from .backing import TieredStore
from .context import ContextStore, WORD, _from_words, _to_words


# --------------------------------------------------------------------------- #
# Alltoallv                                                                    #
# --------------------------------------------------------------------------- #

def alltoallv(
    self,
    store: ContextStore,
    send: str,
    recv: str,
    send_counts: Optional[str] = None,
    recv_counts: Optional[str] = None,
    mode: str = "direct",
    fill=None,
    use_kernel: bool = True,
    procs: Optional[list] = None,
) -> ContextStore:
    """Every VP ρ sends message ``send[d]`` to VP d; after the call VP ρ holds
    ``recv[s] =`` (s's message to ρ) and transposed counts.

    ``send``/``recv`` name ``[v, ω]`` layout fields (``ω`` the per-message
    payload; all byte math below is ``ω`` words × 4 bytes).  ``fill``
    (optional, requires counts) fuses the receiver's boundary mask into
    delivery: lanes past ``send_counts[ρ][d]`` arrive as ``fill`` instead of
    whatever padding the sender left.  ``use_kernel=False`` keeps the seed's
    dense-transpose implementation (bit-identical, for equivalence testing);
    the ledger is unaffected by either knob.

    Sharding/mesh semantics: on the device tier with ``P > 1`` the network
    phase runs over the jax mesh (α-chunked ``lax.all_to_all``, Alg 7.1.3).
    On a backing tier the collective is host-side data movement over the
    (possibly sharded) backing: each destination shard's recv rows are
    staged through a bounded host buffer and written back to that shard
    only, with measured disk bytes billed to the owning shard's ledger.
    ``procs`` (tiered stores only) restricts the *destination* side to the
    listed processes' shards — sources are still read from every shard, but
    nothing outside the listed shards is written (per-process recovery).
    In-place shuffles (``send == recv``) are not per-process recoverable:
    a rerun would re-read already-shuffled source rows.

    Raises ``ValueError`` for unknown ``mode``, mismatched field shapes,
    ``fill`` without counts, ``procs`` on a device store, or a staging
    chunk that cannot fit ``device_cap_bytes``.
    """
    if mode not in ("direct", "indirect"):
        raise ValueError(f"unknown mode {mode!r}")
    if procs is not None and not isinstance(store, TieredStore):
        raise ValueError("procs= requires a backing-tier store")
    cfg = self.cfg
    f = store.layout.field(send)
    if store.layout.field(recv).shape != f.shape:
        raise ValueError("send/recv field shapes must match")
    if f.shape[0] != cfg.v:
        raise ValueError(f"alltoallv fields must be [v, ω]; got {f.shape}")
    if fill is not None and (send_counts is None or recv_counts is None):
        raise ValueError("fill requires send_counts/recv_counts")
    if fill is not None:
        # One early representability check for every implementation path:
        # an out-of-range fill would otherwise wrap silently (or fail deep
        # inside a trace with an opaque cast error).
        from repro.kernels.alltoallv_deliver import check_fill_range
        check_fill_range(fill, f.dtype)
    omega_b = int(_np.prod(f.shape[1:], dtype=_np.int64)) * WORD if len(f.shape) > 1 else WORD

    if isinstance(store, TieredStore):
        store = _alltoallv_host(self, store, send, recv,
                                send_counts, recv_counts, fill, procs)
    elif mode == "direct" and use_kernel:
        if cfg.P == 1:
            store = _alltoallv_fused(self, store, send, recv,
                                     send_counts, recv_counts, fill)
        else:
            store = _alltoallv_fused_mesh(self, store, send, recv,
                                          send_counts, recv_counts, fill)
    else:
        store = _alltoallv_dense(self, store, send, recv,
                                 send_counts, recv_counts, mode, fill)

    _ledger_alltoallv(self, omega_b, mode)
    return store


def _fill_word(fill, dtype) -> _np.uint32:
    """The word-level masking convention, in one place: the bit pattern of
    ``fill`` in the payload field's dtype, as a store word — what every
    raw-word delivery path (P == 1 fused, mesh, tiered host) writes into
    masked lanes so the receiver reads the typed value."""
    return _np.asarray(fill, _np.dtype(dtype)).view(_np.uint32)


# CPU-fallback implementation switch: below this per-message word count the
# whole store is cache-resident and a row-at-a-time fori_loop delivery (one
# strided gather + one in-place row write per destination, ~2 payload copies
# of traffic) beats the vectorised transpose+concat (~4 copies); above it the
# loop's strided gathers thrash and the single fused transpose wins.
_ROW_LOOP_MAX_WW = 768

# Mesh-path landing switch: up to this many per-process payload words the
# received buffer is cache-resident and a dynamic-update-slice write wins;
# above it the concat row rebuild (which fuses the lane split into its
# output loop) is consistently faster on CPU.
_MESH_DUS_MAX_WORDS = 1 << 17


def _alltoallv_fused(self, store, send, recv, send_counts, recv_counts, fill):
    """PEMS2 word-level direct delivery (Alg 7.1.1/7.1.2): slice the send
    field's word range out of the store, deliver through the Pallas kernel
    (counts transpose and boundary mask fused), write the recv range back.
    On backends without compiled Pallas the delivery is a vectorised
    transpose — or, for cache-sized ω, a row-at-a-time in-place loop."""
    from repro.kernels.alltoallv_deliver import deliver_fused, uses_pallas

    cfg = self.cfg
    lo = store.layout
    v = cfg.v
    ww = lo.field_words(send) // v             # ω in store words

    cnt_mask = None
    cnt_words = None
    if send_counts is not None and recv_counts is not None:
        cnt_words = store.field_words_view(send_counts)      # [v, v] raw bits
        if fill is not None:
            cnt_mask = store.field(send_counts).reshape(v, v)

    fill_word = None
    if fill is not None:
        fill_word = int(_fill_word(fill, lo.field(send).dtype))

    # The row loop writes destination rows while later iterations still read
    # source rows, so it must not run when send and recv alias the same
    # field; the vectorised path reads the whole block before writing.
    if not uses_pallas() and ww <= _ROW_LOOP_MAX_WW and send != recv:
        store = _deliver_rows_inplace(store, send, recv, cnt_mask, fill_word)
        ct = None if cnt_words is None else jnp.swapaxes(cnt_words, 0, 1)
    else:
        W = store.field_words_view(send).reshape(v, v, ww)
        out, ct = deliver_fused(W, cnt_mask, cnt_words, fill=fill_word)
        store = store.with_field_words(recv, out.reshape(v, v * ww))
    if cnt_words is not None:
        cs = lo.field(send_counts).dtype
        cr = lo.field(recv_counts).dtype
        if cs == cr:
            store = store.with_field_words(recv_counts, ct)
        else:
            store = store.with_field(
                recv_counts, _from_words(ct, cs).astype(cr)
            )
    return store


def _deliver_rows_inplace(store, send, recv, counts_i32, fill_word):
    """Row-at-a-time direct delivery: for each destination d, gather column
    d's message from every source context and write it straight into d's
    recv word range.  The fori_loop carry lets XLA update the store buffer
    in place — the closest host analogue of the thesis writing each message
    directly into the destination context on disk."""
    lo = store.layout
    v = store.v
    off_s = lo.offset(send)
    off_r = lo.offset(recv)
    ww = lo.field_words(send) // v
    nw = v * ww

    def body(d, dat):
        col = lax.dynamic_slice(dat, (0, off_s + d * ww), (v, ww))
        if fill_word is not None:
            cnt = lax.dynamic_slice(counts_i32, (0, d), (v, 1))
            lane = lax.broadcasted_iota(jnp.int32, (v, ww), 1)
            col = jnp.where(lane < cnt.astype(jnp.int32),
                            col, jnp.uint32(fill_word))
        return lax.dynamic_update_slice(dat, col.reshape(1, nw), (d, off_r))

    return ContextStore(store.layout, lax.fori_loop(0, v, body, store.data))


def _alltoallv_fused_mesh(self, store, send, recv, send_counts, recv_counts,
                          fill):
    """PEMS2 word-level direct delivery over the ``P > 1`` mesh: assemble →
    ship → land, Alg 7.1.3's structure at the word level.

    Each chunk is *assembled* straight from the send field's raw word range
    by the (src_proc, dst_proc)-tiled kernel — destination-ordered staging
    with the receiver's boundary mask applied at the source and the counts
    transpose fused into the same pass — then *shipped* through
    ``lax.all_to_all`` (payload and transposed counts as two aligned
    buffers of the same collective round), and the received buffer *lands*
    in the destination rows verbatim: no receive-side transpose exists, and
    the dense ``[m, v, ω]`` per-process staging of ``_global_transpose``
    never materializes.

    Default (``alpha=None``, unchunked): a single all_to_all feeding one
    concat-based row rebuild (whose output loop XLA fuses the lane split
    into — the ``with_field_words`` trick).  With ``alpha`` set the network
    phase is α-chunked: one ``[k, P, α, ω]`` buffer per (source round of k
    (§6.5), destination α-chunk) — ≤ α·k·ω payload words per (source,
    destination) process pair, the Lemma 7.1.9 bound — landed in place
    chunk by chunk.  Bounded buffers cost extra collective launches; the
    knob exists for memory-bounded staging (and the tiered ``P > 1`` path
    to come), not for speed.
    """
    from repro.kernels.alltoallv_deliver import assemble_proc_fused

    from .executor import _shard_map
    shard_map = _shard_map()

    cfg = self.cfg
    lo = store.layout
    v, Pn, m, k = cfg.v, cfg.P, cfg.v_local, cfg.k
    alpha = cfg.alpha
    ww = lo.field_words(send) // v             # ω in store words
    off_s, off_r = lo.offset(send), lo.offset(recv)
    has_counts = send_counts is not None and recv_counts is not None
    if has_counts:
        off_c, off_rc = lo.offset(send_counts), lo.offset(recv_counts)
        cs = lo.field(send_counts).dtype
        cr = lo.field(recv_counts).dtype

    fill_word = None
    if fill is not None:
        fill_word = int(_fill_word(fill, lo.field(send).dtype))

    def conv_ct(ct):
        if cs == cr:
            return ct
        return _to_words(_from_words(ct, cs).astype(cr))

    def ship(xc, cm, cp):
        """Assemble one chunk [s, P, d, ww] into destination order (mask +
        counts transpose fused), all_to_all payload and counts, returning
        payload [d, P, s, ww] and counts words [d, P, s] (or None) — both
        already in the destination rows' slot order."""
        out, ct = assemble_proc_fused(xc, cm, cp, fill=fill_word)
        y = lax.all_to_all(out, cfg.vp_axis, split_axis=0,
                           concat_axis=1, tiled=False)  # [d, P(src), s, ww]
        if ct is None:
            return y, None
        yc = lax.all_to_all(ct, cfg.vp_axis, split_axis=0,
                            concat_axis=1, tiled=False)  # [d, P(src), s]
        return y, yc

    def f(local):                              # [m, words]: this proc's rows
        # Word-level send matrix: W[sl, dp, dl] is row sl's ω-words for
        # global destination dp·m + dl (sliced once; functional, so the
        # recv writes below cannot corrupt it even when send == recv).
        W = lax.slice(local, (0, off_s), (m, off_s + v * ww))
        W = W.reshape(m, Pn, m, ww)
        C_w = C_i = None
        if has_counts:
            C_w = lax.slice(local, (0, off_c), (m, off_c + v))
            C_w = C_w.reshape(m, Pn, m)
            if fill is not None:
                C_i = _from_words(C_w, cs).astype(jnp.int32)

        if alpha is None:
            # Unchunked: one assembly, one all_to_all, one row landing.
            pay, ct = ship(W, C_i, C_w)        # [m, P, m, ww], [m, P, m]
            if m * v * ww <= _MESH_DUS_MAX_WORDS:
                new = lax.dynamic_update_slice(
                    local, pay.reshape(m, v * ww), (0, off_r))
            else:
                left = lax.slice(local, (0, 0), (m, off_r))
                right = lax.slice(
                    local, (0, off_r + v * ww), (m, local.shape[1]))
                new = jnp.concatenate(
                    [left, pay.reshape(m, v * ww), right], axis=1)
            if has_counts:
                # After the landing: `new` has a single consumer, so XLA
                # updates it in place (before it, the update would copy the
                # whole row block — `local` is still pinned by the slices).
                new = lax.dynamic_update_slice(
                    new, conv_ct(ct.reshape(m, v)), (0, off_rc))
            return new

        for s0 in range(0, m, k):              # source rounds of k (§6.5)
            for c0 in range(0, m, alpha):      # destination α-chunks
                c1 = min(c0 + alpha, m)
                xc = W[s0:s0 + k, :, c0:c1, :]          # [k, P, c, ww]
                cm = cp = None
                if has_counts:
                    cp = C_w[s0:s0 + k, :, c0:c1]
                    if fill is not None:
                        cm = C_i[s0:s0 + k, :, c0:c1]
                pay, ct = ship(xc, cm, cp)     # [c, P, k, ww], [c, P, k]
                if has_counts:
                    ct = conv_ct(ct)
                # Land in place: each source process' slots are a
                # contiguous word range of the destination rows.
                for q in range(Pn):
                    local = lax.dynamic_update_slice(
                        local, pay[:, q].reshape(c1 - c0, k * ww),
                        (c0, off_r + (q * m + s0) * ww),
                    )
                    if has_counts:
                        local = lax.dynamic_update_slice(
                            local, ct[:, q], (c0, off_rc + q * m + s0),
                        )
        return local

    data = shard_map(
        f,
        mesh=self.mesh,
        in_specs=(P(cfg.vp_axis, None),),
        out_specs=P(cfg.vp_axis, None),
    )(store.data)
    return ContextStore(lo, data)


def _alltoallv_dense(self, store, send, recv, send_counts, recv_counts,
                     mode, fill):
    """Dense-transpose data path: the PEMS1 indirect baseline, the α-chunked
    ``P > 1`` network path, and the ``use_kernel=False`` reference."""
    cfg = self.cfg
    f = store.layout.field(send)

    M = store.field(send)                      # [v, v, ω...]
    M = M.reshape(cfg.v, cfg.v, -1)

    if mode == "indirect":
        # PEMS1: stage every message in the indirect area first.  The barrier
        # forces the staging copy to materialise.
        M = jax.lax.optimization_barrier(M)

    Mt = _global_transpose(self, M)            # [v, v, ω] with axes (dst, src)
    Ct = None
    if send_counts is not None and recv_counts is not None:
        C = store.field(send_counts).reshape(cfg.v, cfg.v, 1)
        if mode == "indirect":
            C = jax.lax.optimization_barrier(C)
        Ct = _global_transpose(self, C)        # transposed once, reused below
    if fill is not None:
        lane = jax.lax.broadcasted_iota(jnp.int32, Mt.shape, 2)
        Mt = jnp.where(lane < Ct[..., 0][..., None].astype(jnp.int32),
                       Mt, jnp.asarray(fill, Mt.dtype))
    store = store.with_field(recv, Mt.reshape((cfg.v,) + f.shape))
    if Ct is not None:
        store = store.with_field(
            recv_counts, Ct.reshape(cfg.v, cfg.v).astype(
                store.layout.field(recv_counts).dtype)
        )
    return store


def _alltoallv_host(self, store, send, recv, send_counts, recv_counts, fill,
                    procs=None):
    """Backing-tier Alltoallv: pure host-side data movement over the
    host/memmap store — messages move straight between context rows of the
    backing array, the closest real-world analogue of the thesis writing
    each message directly into the destination context on disk.  Bit-
    identical to the device paths (copies only, no arithmetic).

    The staging is chunked *per destination process, then by α* (the α knob,
    Alg 7.1.3 applied host-side): each chunk stages ``[αd, v, ω]`` — every
    source's messages for αd of process p's destination contexts — masks it
    in place, and writes it straight into those destinations' recv word
    ranges, which live entirely in shard p.  This is the per-process host
    buffer of the parallel disk model: sources are read from every shard
    (and billed to each source shard's ledger), but each chunk writes one
    destination shard only, so a ``procs`` subset re-runs without touching
    the other shards' bytes.  ``device_cap_bytes`` (the memory budget the
    backing tier exists to honour) bounds the staging buffer *per process*:
    αd is clamped so the chunk fits, instead of materializing the dense
    ``[v, v, ω]`` matrix the tier cannot afford.  An in-place shuffle
    (``send == recv``) additionally snapshots the whole field — a chunked
    in-place transpose would read rows it has already overwritten — and
    raises when snapshot + chunk cannot fit the cap."""
    cfg = self.cfg
    v, m = cfg.v, cfg.v_local
    lo = store.layout
    bk = store.backing
    # Array-addressable backings (host/memmap) stage straight from a view;
    # the engine-backed file tier — and the sharded backing, which has no
    # whole-population array by design — reads its chunk through the block
    # API.  Checksummed backings also take the block API so every staged
    # byte is CRC-verified — a raw view would bypass torn-write detection.
    arr = (None if getattr(bk, "checksum", None) is not None
           else getattr(bk, "arr", None))
    disk = store.on_disk
    ww = lo.field_words(send) // v                 # ω in store words
    off_s, off_r = lo.offset(send), lo.offset(recv)
    procs = list(range(cfg.P)) if procs is None else list(procs)

    Ct = None
    if send_counts is not None and recv_counts is not None:
        Ct = store.field(send_counts).reshape(v, v).T.copy()
    fill_word = None
    if fill is not None:
        fill_word = _fill_word(fill, lo.field(send).dtype)

    alpha = m if cfg.alpha is None else cfg.alpha
    # Host/memmap chunks are sliced as views; the engine-backed file tier's
    # read_block returns a *copy* the same size as the staging buffer, so a
    # chunk there holds 2x its column bytes resident (copy + blk).  The
    # in-place path slices views off the snapshot either way.
    chunk_copies = 1 if (arr is not None or send == recv) else 2
    if cfg.device_cap_bytes is not None:
        per_dst = chunk_copies * v * ww * WORD     # one destination column
        if per_dst > cfg.device_cap_bytes:
            raise ValueError(
                f"alltoallv staging needs {per_dst:,} bytes per destination "
                f"([v, ω] = [{v}, {ww * WORD}B] x{chunk_copies}) but "
                f"device_cap_bytes={cfg.device_cap_bytes:,}; raise the cap "
                "or shrink ω"
            )
        alpha = min(alpha, cfg.device_cap_bytes // per_dst)
    full = None
    if send == recv:
        # In-place shuffle: later chunks would read rows already
        # overwritten, so the whole field is snapshotted once and the
        # (still α-chunked) loop reads from the snapshot.  The snapshot
        # itself is v·v·ω staging — refuse when the cap cannot cover
        # snapshot + chunk rather than silently blowing the budget.
        full_bytes = v * v * ww * WORD
        if (cfg.device_cap_bytes is not None
                and full_bytes + alpha * v * ww * WORD
                > cfg.device_cap_bytes):
            raise ValueError(
                f"in-place tiered alltoallv (send == recv) must snapshot "
                f"the whole field ({full_bytes:,} B) on top of the "
                f"{alpha * v * ww * WORD:,} B chunk, exceeding "
                f"device_cap_bytes={cfg.device_cap_bytes:,}; use distinct "
                "send/recv fields or raise the cap"
            )
        full = bk.read_block(0, v, cols=slice(off_s, off_s + v * ww))
        if disk:
            self._account_disk(0, v, v * ww * WORD, write=False)

    for p in procs:
        stats = self.shard_stats[p]
        # One span per destination process's network phase, one per α-chunk
        # inside it (Alg 7.1.3 made visible): the trace shows exactly which
        # chunk of which shard's delivery the run spent its time in.
        with self.tracer.span(f"alltoallv.p{p}", tid="collective",
                              cat="collective", alpha=alpha):
            _alltoallv_proc_chunks(
                self, p, m, v, ww, alpha, arr, full, disk, off_s, off_r,
                fill, fill_word, Ct, bk, stats, chunk_copies)
    if Ct is not None:
        ct = Ct.astype(lo.field(recv_counts).dtype)
        for p in procs:
            store.with_field_rows(recv_counts, p * m, ct[p * m:(p + 1) * m])
    return store


def _alltoallv_proc_chunks(self, p, m, v, ww, alpha, arr, full, disk,
                           off_s, off_r, fill, fill_word, Ct, bk, stats,
                           chunk_copies):
    """The α-chunk loop of :func:`_alltoallv_host` for one destination
    process ``p`` — split out so each chunk can carry its own trace span
    without deepening the host loop."""
    for c0 in range(p * m, (p + 1) * m, alpha):
        with self.tracer.span("chunk", tid="collective", cat="collective",
                              dst=p, c0=c0):
            c1 = min(c0 + alpha, (p + 1) * m)
            if full is not None:
                cols = full[:, c0 * ww:c1 * ww]
            elif arr is not None:
                cols = arr[:, off_s + c0 * ww:off_s + c1 * ww]
            else:
                cols = bk.read_block(
                    0, v, cols=slice(off_s + c0 * ww, off_s + c1 * ww))
            blk = _np.empty((c1 - c0, v, ww), _np.uint32)  # staging buffer
            blk[...] = _np.swapaxes(cols.reshape(v, c1 - c0, ww), 0, 1)
            if disk and full is None:
                # The chunk reads (c1-c0)·ω columns of every source row —
                # split across the source shards' ledgers.
                self._account_disk(0, v, (c1 - c0) * ww * WORD, write=False)
            stats.peak_stage_bytes = max(
                stats.peak_stage_bytes,
                chunk_copies * blk.nbytes
                + (full.nbytes if full is not None else 0),
            )
            if fill is not None:
                lane = _np.arange(ww)[None, None, :]
                _np.copyto(blk, fill_word,
                           where=lane >= Ct[c0:c1, :, None].astype(_np.int64))
            bk.write_block(c0, c1, blk.reshape(c1 - c0, v * ww),
                           cols=slice(off_r, off_r + v * ww))
            if disk:
                # The writes land entirely in destination shard p.
                self._account_disk(c0, c1, v * ww * WORD, write=True)


def _global_transpose(self, M: jnp.ndarray) -> jnp.ndarray:
    """[v(src), v(dst), w] → [v(dst), v(src), w], sharded on axis 0 over the
    vp axis when P > 1 (α-chunked all_to_all, Alg 7.1.3)."""
    cfg = self.cfg
    if cfg.P == 1:
        return jnp.swapaxes(M, 0, 1)

    from .executor import _shard_map
    shard_map = _shard_map()

    m = cfg.v_local
    Pn = cfg.P
    alpha = m if cfg.alpha is None else cfg.alpha
    w = M.shape[-1]

    def f(local):                              # [m(src_local), v, w]
        x = local.reshape(m, Pn, m, w)         # (src_local, dst_proc, dst_local, w)
        chunks = []
        for c0 in range(0, m, alpha):
            c1 = min(c0 + alpha, m)
            xc = x[:, :, c0:c1, :]             # bounded buffer: α·ω per lane
            yc = lax.all_to_all(
                xc, cfg.vp_axis, split_axis=1, concat_axis=0, tiled=False
            )                                   # [P(src_proc), m, c, w]
            chunks.append(yc)
        y = jnp.concatenate(chunks, axis=2) if len(chunks) > 1 else chunks[0]
        y = y.reshape(Pn * m, m, w)            # (src_global, dst_local, w)
        return jnp.swapaxes(y, 0, 1)           # (dst_local, src_global, w)

    return shard_map(
        f,
        mesh=self.mesh,
        in_specs=(P(cfg.vp_axis, None, None),),
        out_specs=P(cfg.vp_axis, None, None),
    )(M)


def _ledger_alltoallv(self, omega_b: int, mode: str) -> None:
    cfg = self.cfg
    B = cfg.block_bytes
    v, k, Pn = cfg.v, cfg.k, cfg.P
    m = cfg.v_local
    mu = self.layout.live_bytes
    led = self.ledger

    if mode == "direct":
        # Alg 7.1.1 / 7.1.2 event counts (validated vs Lemma 7.1.3 and the
        # exact parallel model in analysis.pems2_alltoallv_par_io_exact).
        delta = (m * m + m * k) // 2           # ID-ordered rounds, per proc
        led.add_swap_out(v * max(mu - v * omega_b, 0), B)
        led.add_msg_direct(Pn * delta * omega_b, B)
        led.add_msg_indirect(Pn * 2 * (m * m - delta) * omega_b, B)
        if Pn > 1:
            led.add_network(v * (v - m) * omega_b)
            led.add_msg_direct(v * (v - m) * omega_b, B)
            # Network launches: one bulk all-to-all when unchunked, else one
            # per (source round of k, destination α-chunk) — Alg 7.1.3,
            # validated against analysis.pems2_alltoallv_par_network_rounds.
            if cfg.alpha is None:
                led.add_network_rounds(1)
            else:
                led.add_network_rounds((m // k) * -(-m // cfg.alpha))
        led.add_boundary(2 * v * v * B, B)
        led.add_barrier(3)
    else:
        # Alg 2.2.1 event counts (Lemma 2.2.1: 4vμ + 2v²ω) + §2.3.3 indirect
        # network routing (each remote message crosses the wire twice).
        led.add_msg_indirect(v * v * omega_b, B)      # write to indirect area
        led.add_swap_out(v * mu, B)
        led.add_swap_in(v * mu, B)
        led.add_msg_indirect(v * v * omega_b, B)      # read back for delivery
        led.add_swap_out(v * mu, B)
        led.add_swap_in(v * mu, B)
        if Pn > 1:
            led.add_network(2 * v * (v - m) * omega_b)
        led.require_disk(v * mu // Pn + v * v * omega_b)
        led.add_barrier(2)


# --------------------------------------------------------------------------- #
# Rooted collectives (§7.2–7.4) — global-array ops; GSPMD inserts the network  #
# collectives, the ledger carries the thesis' worst-case EM terms.             #
# --------------------------------------------------------------------------- #

def bcast(self, store: ContextStore, field: str, root: int = 0,
          procs=None) -> ContextStore:
    """EM-Bcast (Alg 7.2.1): root's field value lands in every context.

    On a tiered store ``procs`` restricts the write side to the listed
    processes' shards (the root row is read wherever it lives)."""
    cfg = self.cfg
    if procs is not None and not isinstance(store, TieredStore):
        raise ValueError("procs= requires a backing-tier store")
    if isinstance(store, TieredStore):
        # Read only the root context's field range off the backing store.
        m = cfg.v_local
        off = store.layout.offset(field)
        nw = store.layout.field_words(field)
        row = store.backing.read_block(root, root + 1,
                                       cols=slice(off, off + nw))
        if store.on_disk:
            self._account_disk(root, root + 1, row.nbytes, write=False)
        for p in (range(cfg.P) if procs is None else procs):
            store.backing.write_block(p * m, (p + 1) * m, row,  # every row
                                      cols=slice(off, off + nw))
            if store.on_disk:
                self._account_disk(p * m, (p + 1) * m, row.nbytes,
                                   write=True)
    else:
        vals = store.field(field)              # [v, ...]
        val = lax.dynamic_index_in_dim(vals, root, axis=0, keepdims=False)
        out = jnp.broadcast_to(val, vals.shape)
        store = store.with_field(field, out)

    B = cfg.block_bytes
    mu = self.layout.live_bytes
    omega_b = self.layout.field_bytes(field)
    # Lemma 7.2.1: root-partition sharers swap out and back in; every VP
    # delivers ω to its context.
    self.ledger.add_swap_out(cfg.v * mu // (cfg.P * cfg.k), B)
    self.ledger.add_swap_in(cfg.v * mu // (cfg.P * cfg.k), B)
    self.ledger.add_msg_direct(cfg.v * omega_b, B)
    if cfg.P > 1:
        self.ledger.add_network((cfg.P - 1) * omega_b)
    self.ledger.add_barrier()
    return store


def gather(self, store: ContextStore, send: str, recv: str, root: int = 0,
           procs=None) -> ContextStore:
    """EM-Gather (Alg 7.3.1): every VP's ``send`` ([ω]) lands in the root's
    ``recv`` ([v, ω]).  Non-root recv fields are left untouched.

    On a tiered store ``procs`` restricts the write side: the root row is
    only written when its shard (``root // (v/P)``) is listed."""
    cfg = self.cfg
    fs = store.layout.field(send)
    fr = store.layout.field(recv)
    if fr.shape != (cfg.v,) + fs.shape:
        raise ValueError(f"recv must be [v, *send.shape]; got {fr.shape}")
    if procs is not None and not isinstance(store, TieredStore):
        raise ValueError("procs= requires a backing-tier store")
    if isinstance(store, TieredStore):
        A = store.field(send)                  # host copy [v, ...]
        w = _np.ascontiguousarray(A.astype(_np.dtype(fr.dtype))).reshape(-1)
        off = store.layout.offset(recv)
        # Only the root context's recv range is touched on the backing store.
        if procs is None or root // cfg.v_local in procs:
            store.backing.write_block(root, root + 1,
                                      w.view(_np.uint32)[None],
                                      cols=slice(off, off + w.size))
            if store.on_disk:
                self._account_disk(root, root + 1, w.nbytes, write=True)
    else:
        A = store.field(send)                  # [v, ...] gathered result
        R = store.field(recv)                  # [v, v, ...]
        R = R.at[root].set(A.astype(fr.dtype))
        store = store.with_field(recv, R)

    B = cfg.block_bytes
    omega_b = self.layout.field_bytes(send)
    # Lemma 7.3.1 (exact form): the root may swap out (μ) and the gathered
    # v·ω result is written to its context on disk.
    self.ledger.add_swap_out(self.layout.live_bytes, B)
    self.ledger.add_msg_direct(cfg.v * omega_b, B)
    if cfg.P > 1:
        self.ledger.add_network((cfg.v - cfg.v_local) * omega_b)
    self.ledger.add_barrier()
    return store


def allgather(self, store: ContextStore, send: str, recv: str,
              procs=None) -> ContextStore:
    """Every VP receives every VP's ``send`` into ``recv`` ([v, ω]).

    On a tiered store ``procs`` restricts the write side to the listed
    processes' shards (sources are read from every shard)."""
    cfg = self.cfg
    if procs is not None and not isinstance(store, TieredStore):
        raise ValueError("procs= requires a backing-tier store")
    if isinstance(store, TieredStore):
        # Stage only the gathered [v, ω] row (every receiver gets the same
        # bytes) and write it per destination shard — never the dense
        # [v, v·ω] broadcast the tier cannot afford.
        m = cfg.v_local
        A = store.field(send)                  # host copy [v, ...]
        w = _np.ascontiguousarray(
            A.astype(_np.dtype(store.layout.field(recv).dtype))).reshape(-1)
        off = store.layout.offset(recv)
        for p in (range(cfg.P) if procs is None else procs):
            store.backing.write_block(p * m, (p + 1) * m,
                                      w.view(_np.uint32)[None],
                                      cols=slice(off, off + w.size))
            if store.on_disk:
                self._account_disk(p * m, (p + 1) * m, w.nbytes, write=True)
            st = self.shard_stats[p]
            st.peak_stage_bytes = max(st.peak_stage_bytes, w.nbytes)
    else:
        A = store.field(send)                  # [v, ...]
        out = jnp.broadcast_to(
            A[None], (cfg.v,) + A.shape
        ).astype(store.layout.field(recv).dtype)
        store = store.with_field(recv, out)
    # An allgather is an Alltoallv with equal messages — same ledger shape.
    _ledger_alltoallv(self, self.layout.field_bytes(send), "direct")
    return store


def reduce(self, store: ContextStore, field: str, out_field: str,
           op: str = "add", root: int = 0, procs=None) -> ContextStore:
    """EM-Reduce (Alg 7.4.1): vectorised reduction of each VP's ``field``
    ([n]) into the root's ``out_field`` ([n]).

    On a tiered store ``procs`` gates the root write like :func:`gather`."""
    if procs is not None and not isinstance(store, TieredStore):
        raise ValueError("procs= requires a backing-tier store")
    if isinstance(store, TieredStore):
        red = _tiered_reduce(self, store, field, op)
        fr = store.layout.field(out_field)
        w = _np.ascontiguousarray(
            red.astype(_np.dtype(fr.dtype))).reshape(-1)
        off = store.layout.offset(out_field)
        if procs is None or root // self.cfg.v_local in procs:
            store.backing.write_block(root, root + 1,
                                      w.view(_np.uint32)[None],
                                      cols=slice(off, off + w.size))
            if store.on_disk:
                self._account_disk(root, root + 1, w.nbytes, write=True)
    else:
        vals = store.field(field)              # [v, n]
        red = _reduce_op(op)(vals)
        R = store.field(out_field)
        R = R.at[root].set(red.astype(R.dtype))
        store = store.with_field(out_field, R)
    _ledger_reduce(self, self.layout.field_bytes(out_field))
    return store


def allreduce(self, store: ContextStore, field: str, out_field: str,
              op: str = "add", procs=None) -> ContextStore:
    if procs is not None and not isinstance(store, TieredStore):
        raise ValueError("procs= requires a backing-tier store")
    if isinstance(store, TieredStore):
        m = self.cfg.v_local
        red = _tiered_reduce(self, store, field, op)
        out = _np.broadcast_to(red[None], (m,) + red.shape).astype(
            _np.dtype(store.layout.field(out_field).dtype))
        for p in (range(self.cfg.P) if procs is None else procs):
            store.with_field_rows(out_field, p * m, out)
    else:
        vals = store.field(field)
        red = _reduce_op(op)(vals)
        out = jnp.broadcast_to(red[None], vals.shape)
        store = store.with_field(
            out_field, out.astype(store.layout.field(out_field).dtype)
        )
    _ledger_reduce(self, self.layout.field_bytes(out_field))
    # The rebroadcast delivers n·ω to every context.
    self.ledger.add_msg_direct(
        (self.cfg.v - 1) * self.layout.field_bytes(out_field),
        self.cfg.block_bytes,
    )
    return store


def _tiered_reduce(self, store, field: str, op: str) -> _np.ndarray:
    """Reduce a backing-tier field.  The reduction itself runs on device
    (same jnp op, same accumulation order) so the result is bit-identical to
    the device tier even for float32 fields; the field matrix [v, n] is
    assumed to fit the device budget (reduce operands are collective-sized,
    not data-sized)."""
    vals = store.field(field)
    red = _np.asarray(_reduce_op(op)(jax.device_put(vals)))
    self.ledger.add_tier_in(vals.nbytes, disk=False)
    self.ledger.add_tier_out(red.nbytes, disk=False)
    return red


def _reduce_op(op: str):
    ops = {
        "add": lambda x: jnp.sum(x, axis=0),
        "max": lambda x: jnp.max(x, axis=0),
        "min": lambda x: jnp.min(x, axis=0),
    }
    if op not in ops:
        raise ValueError(f"unsupported reduce op {op!r} (PEMS requires "
                         "commutative+associative operators, §7.4)")
    return ops[op]


def _ledger_reduce(self, n_bytes: int) -> None:
    cfg = self.cfg
    # Lemma 7.4.2: the root delivers the n-vector result to its context; the
    # network phase is a logarithmic tree (Lemma 7.4.3).
    self.ledger.add_msg_direct(n_bytes, cfg.block_bytes)
    if cfg.P > 1:
        import math
        self.ledger.add_network(n_bytes * math.ceil(math.log2(cfg.P)))
    self.ledger.add_barrier(2)
