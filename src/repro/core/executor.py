"""The PEMS2 superstep executor.

Simulates ``v`` virtual processors on ``P`` real processors (mesh devices)
with ``k`` concurrently-resident contexts per real processor, exactly the
thesis' model (§3.2): execution proceeds in deterministic ID-ordered rounds of
``P·k`` virtual processors (§6.5 — this ordering is what guarantees full disk
parallelism and fixes the direct-delivery count δ).

Drivers (§5):
  * ``explicit`` — every round swaps the full *live* context in and out
    (PEMS2 swaps only allocated bytes, §6.6).
  * ``sliced``   — the superstep declares which fields it reads/writes; only
    those bytes move.  This is the memory-mapped driver of §5.2 made exact:
    JAX traces are static, so "which pages get touched" is known, not guessed.
  * ``async``    — double-buffered rounds: the next round's swap-in is issued
    before the current round's compute completes so XLA can overlap the copy
    with compute (the STXXL-file driver of §5.1).

All drivers produce bit-identical results; they differ in bytes moved (the
ledger) and in schedule (wall-clock benchmarks).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .context import Ctx, ContextLayout, ContextStore, WORD, init_store
from .iostats import IOLedger

DRIVERS = ("explicit", "sliced", "async")


def _shard_map():
    """jax >= 0.8 exports shard_map at top level; older releases keep it in
    jax.experimental."""
    try:
        from jax import shard_map
    except ImportError:
        from jax.experimental.shard_map import shard_map
    return shard_map


@dataclasses.dataclass
class PemsConfig:
    """Simulation parameters (thesis Appendix B.3)."""

    v: int                      # total virtual processors
    k: int = 1                  # concurrently-resident contexts per real proc
    P: int = 1                  # real processors (mesh axis size)
    block_bytes: int = 4096     # B — ledger block size
    driver: str = "explicit"
    alpha: Optional[int] = None  # Alltoallv network chunk (messages at once)
    vp_axis: str = "vp"

    def __post_init__(self):
        if self.driver not in DRIVERS:
            raise ValueError(f"unknown driver {self.driver!r}")
        if self.v % self.P:
            raise ValueError("v must be divisible by P")
        if (self.v // self.P) % self.k:
            raise ValueError("v/P must be divisible by k")

    @property
    def v_local(self) -> int:
        return self.v // self.P

    @property
    def rounds(self) -> int:
        return self.v_local // self.k


class Pems:
    """Executor: superstep engine + I/O ledger.  Collective methods are bound
    from :mod:`repro.core.collectives`."""

    def __init__(self, cfg: PemsConfig, layout: ContextLayout,
                 mesh: Optional[Mesh] = None):
        self.cfg = cfg
        self.layout = layout
        self.mesh = mesh
        self.ledger = IOLedger()
        if cfg.P > 1 and mesh is None:
            raise ValueError("P > 1 requires a mesh with the vp axis")
        if mesh is not None and mesh.shape[cfg.vp_axis] != cfg.P:
            raise ValueError(
                f"mesh axis {cfg.vp_axis}={mesh.shape[cfg.vp_axis]} != P={cfg.P}"
            )
        # PEMS2 disk requirement: exactly vμ/P per real processor (§6.3).
        self.ledger.require_disk(cfg.v * layout.mu_bytes // cfg.P)

    # ------------------------------------------------------------------ setup
    def init(self, init_fn=None) -> ContextStore:
        store = init_store(self.layout, self.cfg.v, init_fn)
        if self.mesh is not None:
            spec = P(self.cfg.vp_axis, None)
            store = ContextStore(
                self.layout,
                jax.device_put(store.data, NamedSharding(self.mesh, spec)),
            )
        return store

    def store_spec(self) -> P:
        return P(self.cfg.vp_axis, None)

    # -------------------------------------------------------------- superstep
    def superstep(
        self,
        store: ContextStore,
        fn: Callable[[jnp.ndarray, Ctx], Ctx],
        reads: Optional[Sequence[str]] = None,
        writes: Optional[Sequence[str]] = None,
        name: str = "superstep",
    ) -> ContextStore:
        """Run one computation superstep: ``fn(rho, ctx) -> ctx`` for every
        virtual processor, in rounds of ``P·k``.

        ``reads``/``writes`` declare the touched fields for the ``sliced``
        driver (and tighten the ledger); with the ``explicit``/``async``
        drivers the full live context swaps.
        """
        cfg = self.cfg
        lo = self.layout
        sliced = cfg.driver == "sliced" and reads is not None and writes is not None

        self._ledger_superstep(sliced, reads, writes)

        if sliced:
            body = self._round_body_sliced(fn, list(reads), list(writes))
        else:
            body = self._round_body_full(fn)

        if cfg.P == 1:
            data = self._run_rounds(store.data, body, dev=None)
        else:
            shard_map = _shard_map()

            def per_device(local):
                dev = lax.axis_index(cfg.vp_axis)
                return self._run_rounds(local, body, dev=dev)

            data = shard_map(
                per_device,
                mesh=self.mesh,
                in_specs=(P(cfg.vp_axis, None),),
                out_specs=P(cfg.vp_axis, None),
            )(store.data)
        return ContextStore(lo, data)

    # ----------------------------------------------------------- round bodies
    def _run_rounds(self, local_data, body, dev):
        cfg = self.cfg
        v_local = local_data.shape[0]
        rounds = v_local // cfg.k
        base = jnp.int32(0) if dev is None else dev.astype(jnp.int32) * v_local

        if cfg.driver == "async" and rounds > 1:
            # Double-buffered: carry the prefetched round; issue the next
            # round's swap-in before computing the current one so the copy
            # can overlap compute.
            def sbody(carry, r):
                data, blk = carry  # blk: prefetched round r
                nxt = lax.dynamic_slice_in_dim(
                    data, (r + 1) % rounds * cfg.k, cfg.k, axis=0
                )
                nxt = jax.lax.optimization_barrier(nxt)
                out = body(base + r * cfg.k, blk)
                data = lax.dynamic_update_slice_in_dim(
                    data, out, r * cfg.k, axis=0
                )
                return (data, nxt), None

            first = lax.dynamic_slice_in_dim(local_data, 0, cfg.k, axis=0)
            (data, _), _ = lax.scan(
                sbody, (local_data, first), jnp.arange(rounds)
            )
            return data

        def sbody(data, r):
            blk = lax.dynamic_slice_in_dim(data, r * cfg.k, cfg.k, axis=0)
            out = body(base + r * cfg.k, blk)
            data = lax.dynamic_update_slice_in_dim(data, out, r * cfg.k, axis=0)
            return data, None

        data, _ = lax.scan(sbody, local_data, jnp.arange(rounds))
        return data

    def _round_body_full(self, fn):
        lo = self.layout

        def body(rho0, blk):  # blk: [k, words]
            rhos = rho0 + jnp.arange(self.cfg.k, dtype=jnp.int32)
            return jax.vmap(
                lambda rho, w: fn(rho, Ctx(lo, w)).words
            )(rhos, blk)

        return body

    def _round_body_sliced(self, fn, reads: List[str], writes: List[str]):
        lo = self.layout

        # One precomputed word-index map per declaration set: the union of
        # the declared fields' word ranges, sorted so the gather/scatter is a
        # monotone sweep over the context.  A superstep that declares many
        # fields (PSRS declares up to 3 reads + 3 writes) then costs one
        # take + one scatter per round instead of O(fields) slice ops.
        def index_map(names: List[str]) -> jnp.ndarray:
            ranges = [
                np.arange(lo.offset(n), lo.offset(n) + lo.field_words(n))
                for n in names
            ]
            idx = np.unique(np.concatenate(ranges)) if ranges else np.arange(0)
            return jnp.asarray(idx, jnp.int32)

        read_idx = index_map(reads)
        write_idx = index_map(writes)

        def body(rho0, blk):
            rhos = rho0 + jnp.arange(self.cfg.k, dtype=jnp.int32)

            def one(rho, w):
                # Only the declared read fields are "swapped in"; the rest of
                # the context view is zero-filled (reading undeclared fields
                # is an application bug, as with real mmap-backed paging the
                # bytes simply would not be resident).
                ctx_words = jnp.zeros_like(w).at[read_idx].set(
                    w.take(read_idx), indices_are_sorted=True,
                    unique_indices=True,
                )
                out = fn(rho, Ctx(lo, ctx_words))
                # Only declared writes land back in the store.
                return w.at[write_idx].set(
                    out.words.take(write_idx), indices_are_sorted=True,
                    unique_indices=True,
                )

            return jax.vmap(one)(rhos, blk)

        return body

    # ---------------------------------------------------------------- ledger
    def _ledger_superstep(self, sliced, reads, writes):
        cfg, lo = self.cfg, self.layout
        B = cfg.block_bytes
        if sliced:
            rbytes = sum(lo.field_bytes(n) for n in reads)
            wbytes = sum(lo.field_bytes(n) for n in writes)
        else:
            rbytes = wbytes = lo.live_bytes
        # Every VP swaps in its (touched) context and swaps it back out once
        # per virtual superstep (§6.1: a careful implementation swaps each
        # context in and out exactly once).
        self.ledger.add_swap_in(rbytes * cfg.v, B)
        self.ledger.add_swap_out(wbytes * cfg.v, B)
        self.ledger.add_barrier()

    # ------------------------------------------------------- debugging helper
    def all_rhos(self) -> jnp.ndarray:
        return jnp.arange(self.cfg.v, dtype=jnp.int32)


# Bind collective methods (defined in their own module to keep files focused).
from . import collectives as _collectives  # noqa: E402

Pems.alltoallv = _collectives.alltoallv
Pems.bcast = _collectives.bcast
Pems.gather = _collectives.gather
Pems.reduce = _collectives.reduce
Pems.allreduce = _collectives.allreduce
Pems.allgather = _collectives.allgather
