"""The PEMS2 superstep executor.

Simulates ``v`` virtual processors on ``P`` real processors (mesh devices)
with ``k`` concurrently-resident contexts per real processor, exactly the
thesis' model (§3.2): execution proceeds in deterministic ID-ordered rounds of
``P·k`` virtual processors (§6.5 — this ordering is what guarantees full disk
parallelism and fixes the direct-delivery count δ).

Drivers (§5):
  * ``explicit`` — every round swaps the full *live* context in and out
    (PEMS2 swaps only allocated bytes, §6.6).
  * ``sliced``   — the superstep declares which fields it reads/writes; only
    those bytes move.  This is the memory-mapped driver of §5.2 made exact:
    JAX traces are static, so "which pages get touched" is known, not guessed.
  * ``async``    — double-buffered rounds: the next round's swap-in is issued
    before the current round's compute completes so XLA can overlap the copy
    with compute (the STXXL-file driver of §5.1).

All drivers produce bit-identical results; they differ in bytes moved (the
ledger) and in schedule (wall-clock benchmarks).

Backing tiers (``repro.core.backing``): with ``tier="host"``, ``"memmap"``
or ``"file"`` the full ``[v, words]`` population lives off-device (host RAM,
an ``np.memmap`` file, or a file behind the :mod:`repro.io` engine) and the
round loop becomes a *host-driven* pipeline: each round's ``k`` contexts —
live allocator bytes only (§6.6) — are ``jax.device_put`` onto the device,
computed, and written back.  Under the ``async`` driver a prefetch thread
issues round ``r+1``'s swap-in while round ``r`` computes, so the disk/PCIe
transfer genuinely overlaps compute (the STXXL-file driver, §5.1) rather
than merely reordering on-device copies; on the ``file`` tier the writeback
is additionally left in flight on the engine's submission queue, so round
``r-1``'s swap-out and round ``r+1``'s swap-in overlap round ``r``'s compute
in *both* directions (visible in ``TierStats.rw_overlap_events``).  The
ledger records the measured per-tier traffic alongside the modeled counters,
and ``Pems.tier_stats`` the wall-clock overlap.
"""

from __future__ import annotations

import dataclasses
import os
import time
import weakref
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.io import IO_DRIVERS
from repro.obs import NOOP, Tracer, merge_trace_files, trace_events, \
    write_trace

from .backing import TIERS, TieredStore, make_backing
from .context import (
    Ctx,
    ContextLayout,
    ContextStore,
    field_word_index,
    init_store,
)
from .iostats import IOLedger, TierStats

DRIVERS = ("explicit", "sliced", "async")


def _shard_map():
    """jax >= 0.8 exports shard_map at top level; older releases keep it in
    jax.experimental."""
    try:
        from jax import shard_map
    except ImportError:
        from jax.experimental.shard_map import shard_map
    return shard_map


@dataclasses.dataclass
class PemsConfig:
    """Simulation parameters (thesis Appendix B.3).

    Every knob is documented at length in ``docs/TUNING.md``; the short
    version:

    * ``v``/``k``/``P`` — total virtual processors, concurrently-resident
      contexts per real processor, and real processors.  ``v`` must divide
      by ``P`` and ``v/P`` by ``k``; each real processor simulates its
      ``v/P`` contexts in ``v/(P·k)`` ID-ordered rounds (§6.5).
    * ``driver`` — round swap strategy: ``explicit`` (full live context),
      ``sliced`` (declared fields only), ``async`` (double-buffered
      prefetch, §5.1).  Bit-identical results; different bytes/schedule.
    * ``tier`` — where the ``[v, words]`` population lives: ``device``
      (resident, whole-program jit), ``host`` (RAM), ``memmap`` (disk via
      ``np.memmap``), ``file`` (disk via the :mod:`repro.io` engine).  With
      ``P > 1`` on a non-device tier the backing is **sharded**: each
      process owns rows ``[p·v/P, (p+1)·v/P)`` in its own backing file
      (``backing_path + ".shard<p>"``) with its own engine and its own
      ``pems.shard_ledgers[p]``/``shard_stats[p]`` accounting — the full
      parallel disk model (§6.3), no mesh required.
    * ``alpha`` — Alltoallv chunk: how many destination contexts are staged
      or shipped at once (Alg 7.1.3), ``1 <= alpha <= v/P`` or ``None`` for
      unchunked.  Bounds the staging buffer per Lemma 7.1.9.
    * ``block_bytes`` — B, the *modeled* ledger block size (bytes).
    * ``device_cap_bytes`` — device-memory budget (bytes) for resident
      contexts + collective staging; construction fails if the config
      cannot fit, and tiered collectives clamp their chunks under it.
    * ``backing_path`` — disk tiers: backing file location (created
      sparse at ``v·μ`` bytes; existing contents are reused, never zeroed).
    * ``io_driver``/``io_queue_depth``/``io_retries``/``io_backoff_s`` —
      file tier only: positional-I/O driver (``buffered``/``odirect``/
      ``mmap``, or ``"faulty:<inner>"`` to inject faults), bounded
      in-flight requests, transient-error retries per request, and base
      backoff seconds (doubles per retry).
    * ``fault_spec`` — what the faulty driver injects (grammar in
      :mod:`repro.io.faults`).  A ``shard=N`` clause (requires
      ``0 <= N < P``) targets one shard's driver only — the
      single-disk-failure model.
    * ``checksums`` — disk tiers: per-64KiB-segment CRC sidecars on the
      backing, verified on every read (torn-write detection).
    * ``merge_kernel``/``merge_tile`` — app-level merge stages (PSRS):
      route the merge through the tiled k-way merge kernel
      (:mod:`repro.kernels.kway_merge`) in ``merge_tile``-wide output
      tiles, instead of the dense ``jnp.sort`` re-sort of the received
      buckets.  Bit-identical either way; ``merge_tile`` must be a power
      of two.
    * ``trace``/``trace_path`` — :mod:`repro.obs` span tracing: record
      superstep/round/engine/collective/recovery spans into per-process
      ring buffers (results stay bit-identical; hot paths pay one
      attribute check when off).  ``trace_path`` is where
      :meth:`Pems.export_trace` writes the merged Perfetto JSON (and the
      per-process ``<path>.p<p>`` part files under a sharded backing).

    Raises ``ValueError`` at construction for any invalid combination —
    unknown names, out-of-range ``alpha``, ``io_*`` knobs without
    ``tier="file"``, ``fault_spec`` without a faulty driver or targeting a
    shard ``>= P``, ``checksums`` on a non-disk tier, or indivisible
    ``v``/``P``/``k``.
    """

    v: int                      # total virtual processors
    k: int = 1                  # concurrently-resident contexts per real proc
    P: int = 1                  # real processors (mesh axis size)
    block_bytes: int = 4096     # B — ledger block size
    driver: str = "explicit"
    alpha: Optional[int] = None  # Alltoallv network chunk (messages at once)
    vp_axis: str = "vp"
    tier: str = "device"        # backing tier: device | host | memmap | file
    backing_path: Optional[str] = None   # disk tiers: backing file location
    device_cap_bytes: Optional[int] = None  # device-memory budget for contexts
    io_driver: Optional[str] = None  # file tier: buffered | odirect | mmap
                                     # (or "faulty:<driver>" for injection)
    io_queue_depth: int = 8     # file tier: bounded in-flight engine requests
    io_retries: int = 2         # file tier: transient-error retries/request
    io_backoff_s: float = 0.002  # file tier: base retry backoff (doubles)
    fault_spec: Optional[str] = None  # faulty driver: what to inject
                                      # (see repro.io.faults grammar)
    checksums: bool = False     # disk tiers: per-block CRC sidecar on the
                                # backing file, verified on every read
    merge_kernel: bool = True   # app merge stages: tiled k-way merge kernel
                                # (False = dense jnp.sort re-sort, the seed
                                # path; bit-identical either way)
    merge_tile: int = 256       # k-way merge output tile width (power of
                                # two; one merge grid step per tile)
    trace: bool = False         # repro.obs span tracing (per-process ring
                                # buffers; bit-identical results either way)
    trace_path: Optional[str] = None  # where export_trace() writes the
                                      # merged Perfetto JSON (requires trace)

    def __post_init__(self):
        if self.driver not in DRIVERS:
            raise ValueError(f"unknown driver {self.driver!r}")
        if self.tier not in TIERS:
            raise ValueError(f"unknown tier {self.tier!r} (choose from {TIERS})")
        # The repro.io knobs fail here, at construction, like every other
        # config field — not deep inside make_backing at run time.
        if self.tier == "file":
            if self.io_driver is None:
                self.io_driver = "buffered"
            parts = self.io_driver.split(":")
            base, wrappers = parts[-1], parts[:-1]
            if base not in IO_DRIVERS or not all(
                    w in ("faulty", "sanitize") for w in wrappers):
                raise ValueError(
                    f"unknown io_driver {self.io_driver!r} "
                    f"(choose from {IO_DRIVERS}, optionally wrapped as "
                    "'faulty:<driver>' / 'sanitize:<driver>')"
                )
        elif self.io_driver is not None:
            raise ValueError(
                f"io_driver={self.io_driver!r} requires tier='file' "
                f"(got tier={self.tier!r})"
            )
        if self.fault_spec is not None:
            if "faulty" not in (self.io_driver or "").split(":")[:-1]:
                raise ValueError(
                    "fault_spec requires io_driver='faulty:<driver>' on "
                    f"tier='file' (got io_driver={self.io_driver!r}, "
                    f"tier={self.tier!r})"
                )
            from repro.io.faults import FaultSpec, split_shard_clause
            shard, rest = split_shard_clause(self.fault_spec)
            if shard is not None and shard >= self.P:
                raise ValueError(
                    f"fault_spec targets shard {shard} but P={self.P} "
                    f"(shard indices are 0..P-1)"
                )
            FaultSpec.parse(rest)   # syntax errors fail here
        if self.checksums and self.tier not in ("memmap", "file"):
            raise ValueError(
                f"checksums=True requires a disk tier ('memmap' or 'file'), "
                f"got tier={self.tier!r}"
            )
        if self.io_retries != int(self.io_retries) or self.io_retries < 0:
            raise ValueError(
                f"io_retries={self.io_retries!r} must be an integer >= 0")
        self.io_retries = int(self.io_retries)
        if self.io_backoff_s < 0:
            raise ValueError(
                f"io_backoff_s={self.io_backoff_s!r} must be >= 0")
        if (self.io_queue_depth != int(self.io_queue_depth)
                or self.io_queue_depth < 1):
            raise ValueError(
                f"io_queue_depth={self.io_queue_depth!r} must be an "
                "integer >= 1"
            )
        self.io_queue_depth = int(self.io_queue_depth)
        if (self.merge_tile != int(self.merge_tile) or self.merge_tile < 2
                or int(self.merge_tile) & (int(self.merge_tile) - 1)):
            raise ValueError(
                f"merge_tile={self.merge_tile!r} must be a power-of-two "
                "integer >= 2 (one k-way merge grid step per tile)"
            )
        self.merge_tile = int(self.merge_tile)
        if self.trace_path is not None and not self.trace:
            raise ValueError(
                f"trace_path={self.trace_path!r} requires trace=True "
                "(nothing records spans to export otherwise)"
            )
        if self.v % self.P:
            raise ValueError("v must be divisible by P")
        if (self.v // self.P) % self.k:
            raise ValueError("v/P must be divisible by k")
        if self.alpha is not None:
            # The Alltoallv network chunk (Alg 7.1.3).  alpha=0 used to fall
            # through as "unchunked" (`alpha or m`), and out-of-range values
            # passed straight into the chunk loop; validate here so every
            # consumer (mesh network phase, tiered staging, ledger rounds)
            # sees a sane value.
            if self.alpha != int(self.alpha):
                raise ValueError(
                    f"alpha={self.alpha!r} must be an integer chunk size"
                )
            self.alpha = int(self.alpha)
            if not 1 <= self.alpha <= self.v_local:
                raise ValueError(
                    f"alpha={self.alpha} out of range: the Alltoallv "
                    f"network chunk must satisfy 1 <= alpha <= v/P = "
                    f"{self.v_local} (alpha=None means unchunked, one "
                    "chunk of v/P destinations)"
                )
    @property
    def v_local(self) -> int:
        return self.v // self.P

    @property
    def rounds(self) -> int:
        return self.v_local // self.k


class Pems:
    """Executor: superstep engine + I/O ledger.  Collective methods are bound
    from :mod:`repro.core.collectives`."""

    def __init__(self, cfg: PemsConfig, layout: ContextLayout,
                 mesh: Optional[Mesh] = None):
        self.cfg = cfg
        self.layout = layout
        self.mesh = mesh
        self.ledger = IOLedger()
        self.tier_stats = TierStats()
        # Per-process accounting (the parallel disk model, §6.3).  At
        # P == 1 the shard lists alias the main ledger/stats, so existing
        # single-process call sites see identical numbers either way; at
        # P > 1 each shard's backing bills its own entry and
        # merged_shard_ledger() recovers the P == 1 totals.
        if cfg.P == 1 or cfg.tier == "device":
            self.shard_ledgers = [self.ledger]
            self.shard_stats = [self.tier_stats]
        else:
            self.shard_ledgers = [IOLedger() for _ in range(cfg.P)]
            self.shard_stats = [TierStats() for _ in range(cfg.P)]
        self.backing = None   # last backing this executor created (tiered)
        self.cursors = None   # optional per-process durable SuperstepCursors:
                              # when set, _run_tiered notes round progress
        # repro.obs tracing: the main tracer (stage/superstep/collective
        # lanes, pid 0 on export) plus one tracer per process for the round
        # loop and its shard's engine (pid p+1) — all on one shared epoch so
        # the merged trace has comparable timestamps.  Disabled, everything
        # aliases the NOOP singleton: instrumented code pays one attribute
        # check, and results are bit-identical either way.
        if cfg.trace:
            self.tracer = Tracer(name="main")
            if cfg.tier == "device":
                self.shard_tracers = [self.tracer]
            else:
                self.shard_tracers = [
                    Tracer(epoch=self.tracer.epoch, name=f"shard{p}")
                    for p in range(cfg.P)
                ]
        else:
            self.tracer = NOOP
            self.shard_tracers = [NOOP] * max(1, cfg.P)
        if cfg.P > 1 and cfg.tier == "device" and mesh is None:
            raise ValueError("P > 1 requires a mesh with the vp axis "
                             "(device tier; backing tiers shard instead)")
        if mesh is not None and mesh.shape[cfg.vp_axis] != cfg.P:
            raise ValueError(
                f"mesh axis {cfg.vp_axis}={mesh.shape[cfg.vp_axis]} != P={cfg.P}"
            )
        if cfg.device_cap_bytes is not None:
            # Device-memory budget for contexts: the device tier must fit the
            # whole population; a backing tier needs its in-flight round
            # blocks — input + output, plus the prefetched next block under
            # the double-buffered async driver.
            if cfg.tier == "device":
                need, what = cfg.v * layout.mu_bytes, "v·mu"
            else:
                bufs = 3 if cfg.driver == "async" else 2
                need = bufs * cfg.k * layout.mu_bytes
                what = f"{bufs}·k·mu in-flight round blocks"
            if need > cfg.device_cap_bytes:
                raise ValueError(
                    f"device-resident contexts need {need:,} bytes ({what}) "
                    f"but device_cap_bytes={cfg.device_cap_bytes:,}; "
                    "lower k or use tier='host'/'memmap'/'file'"
                )
        # PEMS2 disk requirement: exactly vμ/P per real processor (§6.3).
        self.ledger.require_disk(cfg.v * layout.mu_bytes // cfg.P)
        for led in self.shard_ledgers:
            led.require_disk(cfg.v * layout.mu_bytes // cfg.P)

    # ------------------------------------------------------ per-process views
    @property
    def cursor(self):
        """The single-process durable cursor (process 0's at ``P > 1``).
        Assigning a cursor here wraps it as a one-element ``cursors`` list —
        the pre-sharding call sites keep working unchanged."""
        return self.cursors[0] if self.cursors else None

    @cursor.setter
    def cursor(self, cur):
        self.cursors = None if cur is None else [cur]

    def merged_shard_ledger(self) -> IOLedger:
        """Sum of the per-shard ledgers — equals the ``P == 1`` ledger's
        measured counters for the same workload (the sharding invariant the
        tier-1 tests pin)."""
        out = IOLedger()
        for led in self.shard_ledgers:
            out = out.merge(led)
        return out

    def merged_shard_stats(self) -> TierStats:
        out = TierStats()
        for st in self.shard_stats:
            out = out.merge(st)
        return out

    # -------------------------------------------------------- observability
    def metrics_snapshot(self) -> dict:
        """Flat metric-name dict subsuming ``TierStats`` and ``IOLedger``:
        ``tier.*``/``ledger.*`` are the run totals (per-shard entries merged
        at ``P > 1``), ``shard<p>.tier.*`` the per-process breakdown.
        Embedded under ``"metrics"`` in exported traces, so the report CLI
        can cross-check span-derived numbers against the counters."""
        m = {}
        stats = (self.merged_shard_stats() if len(self.shard_stats) > 1
                 else self.tier_stats)
        m.update(stats.snapshot())
        led = self.ledger
        for sl in self.shard_ledgers:
            if sl is not led:
                led = led.merge(sl)
        m.update(led.snapshot())
        if len(self.shard_stats) > 1:
            for p, st in enumerate(self.shard_stats):
                m.update(st.snapshot(prefix=f"shard{p}.tier"))
        return m

    def export_trace(self, path: Optional[str] = None) -> str:
        """Write the recorded spans as one Perfetto-loadable JSON trace.

        Under a sharded backing each per-process tracer is first written to
        its own ``<path>.p<p>`` part file, then the parts are merged (each
        keeping its own process lane) with the main tracer's events and the
        :meth:`metrics_snapshot` into ``path`` (default: the config's
        ``trace_path``).  Load the result in https://ui.perfetto.dev or
        summarize it with ``python -m repro.obs report <path>``."""
        path = self.cfg.trace_path if path is None else path
        if path is None:
            raise ValueError(
                "export_trace needs a path (argument or "
                "PemsConfig.trace_path)")
        if not self.cfg.trace:
            raise ValueError(
                "export_trace requires PemsConfig(trace=True) — nothing "
                "recorded spans")
        parts = []
        if self.shard_tracers[0] is not self.tracer:
            for p, tr in enumerate(self.shard_tracers):
                pp = f"{path}.p{p}"
                write_trace(pp, trace_events(tr, pid=p + 1,
                                             process_name=tr.name))
                parts.append(pp)
        main_events = trace_events(self.tracer, pid=0, process_name="main")
        out = merge_trace_files(path, parts, extra_events=main_events,
                                metrics=self.metrics_snapshot())
        for pp in parts:                     # merged: the parts are spent
            try:
                os.unlink(pp)
            except OSError:
                pass
        return out

    def _account_disk(self, r0: int, r1: int, row_bytes: int,
                      write: bool) -> None:
        """Bill measured disk traffic for global rows ``[r0, r1)`` to the
        owning shard ledger(s) — the single main ledger at ``P == 1``."""
        from .backing import shard_row_ranges
        if len(self.shard_ledgers) == 1:
            led = self.shard_ledgers[0]
            (led.add_disk_write if write
             else led.add_disk_read)((r1 - r0) * row_bytes)
            return
        m = self.cfg.v_local
        for p, a, b in shard_row_ranges(m, r0, r1):
            led = self.shard_ledgers[p]
            (led.add_disk_write if write
             else led.add_disk_read)((b - a) * row_bytes)

    # ------------------------------------------------------------------ setup
    def init(self, init_fn=None, tier: Optional[str] = None,
             backing_path: Optional[str] = None):
        """Create the context population.  ``tier`` (default: the config's)
        selects device residency or a host/disk backing store."""
        tier = self.cfg.tier if tier is None else tier
        if tier not in TIERS:
            # Validate the override as early as the config's own tier.
            raise ValueError(f"unknown tier {tier!r} (choose from {TIERS})")
        if tier != "device":
            return self._init_tiered(init_fn, tier,
                                     backing_path or self.cfg.backing_path)
        store = init_store(self.layout, self.cfg.v, init_fn)
        if self.mesh is not None:
            spec = P(self.cfg.vp_axis, None)
            store = ContextStore(
                self.layout,
                jax.device_put(store.data, NamedSharding(self.mesh, spec)),
            )
        return store

    def _init_tiered(self, init_fn, tier: str,
                     backing_path: Optional[str]) -> TieredStore:
        cfg, lo = self.cfg, self.layout
        backing = make_backing(tier, cfg.v, lo.words, backing_path,
                               P=cfg.P,
                               io_driver=cfg.io_driver,
                               io_queue_depth=cfg.io_queue_depth,
                               stats=self.tier_stats, ledger=self.ledger,
                               shard_stats=self.shard_stats,
                               shard_ledgers=self.shard_ledgers,
                               checksum=cfg.checksums,
                               fault_spec=cfg.fault_spec,
                               io_retries=cfg.io_retries,
                               io_backoff_s=cfg.io_backoff_s)
        self.backing = backing
        if cfg.trace:
            # Attach each shard's tracer to its engine and down the driver
            # wrapper chain (faulty/sanitize proxies), duck-typed like the
            # note_submit/note_complete hooks — no constructor churn.
            shards = getattr(backing, "shards", None) or [backing]
            for p, sh in enumerate(shards):
                tr = self.shard_tracers[min(p, len(self.shard_tracers) - 1)]
                eng = getattr(sh, "engine", None)
                if eng is not None:
                    eng.tracer = tr
                f = getattr(sh, "file", None)
                while f is not None:
                    if hasattr(f, "tracer"):
                        f.tracer = tr
                    f = getattr(f, "inner", None)
        store = TieredStore(lo, backing, self.ledger,
                            shard_ledgers=self.shard_ledgers)
        if init_fn is not None:
            # Populate k contexts at a time so the device never holds more
            # than the resident partitions, even during init.
            def one(rho):
                ctx = Ctx(lo, jnp.zeros((lo.words,), jnp.uint32))
                for name, val in init_fn(rho).items():
                    ctx = ctx.set(name, val)
                return ctx.words

            chunk = jax.jit(jax.vmap(one))
            for r0 in range(0, cfg.v, cfg.k):
                rhos = jnp.arange(r0, r0 + cfg.k, dtype=jnp.int32)
                # Init population is input loading, deliberately outside the
                # IOLedger: the Lemma 7.1.7/7.1.9 closed forms (and the
                # pinned measured-vs-modeled tests) cover the algorithm's
                # supersteps, not the one-time load of its input.
                # pems-lint: disable=ledger-balance
                backing.write_block(r0, r0 + cfg.k, np.asarray(chunk(rhos)))
        return store

    def store_spec(self) -> P:
        return P(self.cfg.vp_axis, None)

    # -------------------------------------------------------------- superstep
    def superstep(
        self,
        store: ContextStore,
        fn: Callable[[jnp.ndarray, Ctx], Ctx],
        reads: Optional[Sequence[str]] = None,
        writes: Optional[Sequence[str]] = None,
        name: str = "superstep",
        procs: Optional[Sequence[int]] = None,
        stream: bool = False,
    ) -> ContextStore:
        """Run one computation superstep: ``fn(rho, ctx) -> ctx`` for every
        virtual processor, in rounds of ``P·k``.

        ``reads``/``writes`` declare the touched fields for the ``sliced``
        driver (and tighten the ledger); with the ``explicit``/``async``
        drivers the full live context swaps.

        ``procs`` (tiered stores only) restricts the superstep to the named
        processes' shards — contexts ``[p·v/P, (p+1)·v/P)`` per listed
        ``p`` — touching only those shards' backings/ledgers.  This is the
        per-process recovery entry point: re-running a stage with
        ``procs=[p]`` after shard ``p``'s disk failed leaves the other
        shards byte-for-byte untouched.  Default: every process.

        ``stream`` (disk backing tiers only; ignored elsewhere) marks an
        I/O-bound stage — PSRS's k-way merge over the received buckets —
        whose round swap-ins should be prefetched through the block API
        while the previous round computes *regardless* of the configured
        driver, so merge compute overlaps disk reads even under
        ``driver="explicit"``.  Results are bit-identical (rounds touch
        disjoint rows); ``TierStats.merge_prefetch_events`` counts the
        overlapped swap-ins and ``merge_stall_s`` the residual blocking.
        """
        with self.tracer.span(f"superstep:{name}", tid="supersteps",
                              cat="superstep", driver=self.cfg.driver,
                              stream=stream):
            return self._superstep_impl(store, fn, reads, writes, procs,
                                        stream)

    def _superstep_impl(self, store, fn, reads, writes, procs, stream):
        cfg = self.cfg
        lo = self.layout
        sliced = cfg.driver == "sliced" and reads is not None and writes is not None

        self._ledger_superstep(sliced, reads, writes, procs)

        if isinstance(store, TieredStore):
            return self._superstep_tiered(store, fn, reads, writes, sliced,
                                          procs, stream)
        if procs is not None:
            raise ValueError(
                "procs= is a tiered-store knob (per-shard recovery); the "
                "device tier runs every process in one traced program")

        if sliced:
            body = self._round_body_sliced(fn, list(reads), list(writes))
        else:
            body = self._round_body_full(fn)

        if cfg.P == 1:
            data = self._run_rounds(store.data, body, dev=None)
        else:
            shard_map = _shard_map()

            def per_device(local):
                dev = lax.axis_index(cfg.vp_axis)
                return self._run_rounds(local, body, dev=dev)

            data = shard_map(
                per_device,
                mesh=self.mesh,
                in_specs=(P(cfg.vp_axis, None),),
                out_specs=P(cfg.vp_axis, None),
            )(store.data)
        return ContextStore(lo, data)

    # ------------------------------------------------- tiered (host-driven)
    def _superstep_tiered(self, store: TieredStore, fn, reads, writes,
                          sliced: bool, procs=None,
                          stream: bool = False) -> TieredStore:
        """Host-driven round pipeline over a host/memmap backing store.

        Per round: swap in the round's ``k`` contexts (live/declared words
        only), run the jitted round body on device, swap the results out.
        The ``async`` driver prefetches round ``r+1`` on a worker thread
        while round ``r`` computes (double buffering, §5.1).
        """
        lo = self.layout
        if sliced:
            in_idx = field_word_index(lo, reads)
            out_idx = field_word_index(lo, writes)
        else:
            # Full-context swap, but live allocator bytes only (§6.6).
            in_idx = out_idx = lo.live_word_index()
        body = self._tiered_body(fn, in_idx, out_idx)
        self._run_tiered(store, body, in_idx, out_idx, procs, stream)
        return store

    def _tiered_body(self, fn, in_idx, out_idx):
        lo, k = self.layout, self.cfg.k
        # The index maps are runtime arguments, not trace constants: embedded
        # million-word iota comparisons otherwise send XLA constant folding
        # off a cliff (seconds per superstep compile).
        in_j = None if in_idx is None else jnp.asarray(in_idx, jnp.int32)
        out_j = None if out_idx is None else jnp.asarray(out_idx, jnp.int32)

        # Cache the jitted body per stage function: jax.jit keys on function
        # identity, so a fresh closure here would re-trace and recompile the
        # stage on *every* superstep call (ruinous for big traces like the
        # unrolled k-way merge network).  Everything else the trace depends
        # on is either fixed per executor (lo, k), a runtime argument
        # (rw, in_j/out_j — index *contents* never shape a trace), or part
        # of jit's own cache key (shapes; None-ness via pytree structure).
        cache = getattr(self, "_tiered_body_cache", None)
        if cache is None:
            cache = self._tiered_body_cache = weakref.WeakKeyDictionary()
        body = cache.get(fn)
        if body is None:
            @jax.jit
            def body(rho0, rw, in_i, out_i):   # rw: [k, n_in] uint32
                rhos = rho0 + jnp.arange(k, dtype=jnp.int32)

                def one(rho, r):
                    if in_i is None:
                        w = r
                    else:
                        # Same zero-fill convention as the sliced device
                        # driver: undeclared (or dead) words are simply not
                        # resident.
                        w = jnp.zeros((lo.words,), jnp.uint32).at[in_i].set(
                            r, indices_are_sorted=True, unique_indices=True
                        )
                    out = fn(rho, Ctx(lo, w)).words
                    if out_i is None:
                        return out
                    return out.take(out_i)

                return jax.vmap(one)(rhos, rw)

            try:
                cache[fn] = body
            except TypeError:      # fn not weakref-able: run uncached
                pass

        return lambda rho0, rw: body(rho0, rw, in_j, out_j)

    def _run_tiered(self, store: TieredStore, body, in_idx, out_idx,
                    procs=None, stream: bool = False) -> None:
        """Drive the round pipeline once per (selected) process: process
        ``p`` swaps its own ``v/P`` contexts through its own shard of the
        backing — its own file, engine, ledger, and stats — in ``v/(P·k)``
        rounds.  ``procs=None`` runs every process (ID order, §6.5); a
        subset re-runs only those shards (per-process recovery)."""
        for p in (range(self.cfg.P) if procs is None else procs):
            self._run_tiered_proc(store, body, in_idx, out_idx, p, stream)

    def _run_tiered_proc(self, store: TieredStore, body, in_idx, out_idx,
                         p: int, stream: bool = False) -> None:
        cfg = self.cfg
        stats, led = self.shard_stats[p], self.shard_ledgers[p]
        bk = store.backing
        disk = bk.disk
        k = cfg.k
        base = p * cfg.v_local
        rounds = cfg.v_local // k
        # A streamed stage (PSRS merge) prefetches its round swap-ins on a
        # disk backing under *every* driver — the stage is I/O bound by
        # construction, so the explicit/sliced drivers get the §5.1 overlap
        # for it too.  Bit-identical: rounds touch disjoint context rows.
        streamed = stream and disk and rounds > 1
        use_async = (cfg.driver == "async" or streamed) and rounds > 1
        # The shard whose engine this process drives (the whole backing at
        # P == 1 — the two are the same object then).
        shard = bk.shards[p] if hasattr(bk, "shards") else bk
        # Engine-backed tier + async driver: leave the writeback in flight on
        # the submission queue instead of blocking the round loop — rounds
        # touch disjoint context rows, so the only ordering requirement is
        # the final drain.  Round r's compute then overlaps round r+1's
        # swap-in (prefetch thread) AND round r-1's swap-out (engine queue):
        # true read+write overlap, measured by TierStats.rw_overlap_events.
        async_writeback = (use_async
                           and getattr(shard, "engine", None) is not None)
        # Span lane for this process: the prefetch thread's swap_in spans
        # land on their own tid, so the Perfetto view shows them genuinely
        # overlapping the rounds lane's compute spans.  Every complete()
        # below reuses the exact t0/t1 the stats were billed with — the
        # trace and TierStats can never disagree.
        tracer = self.shard_tracers[min(p, len(self.shard_tracers) - 1)]

        def fetch(r):
            t0 = time.perf_counter()
            r0 = base + r * k
            h = bk.read_block(r0, r0 + k, cols=in_idx)
            d = jax.device_put(h)
            d.block_until_ready()
            led.add_tier_in(h.nbytes, disk)
            t1 = time.perf_counter()
            stats.swap_in_s += t1 - t0
            tracer.complete("swap_in", t0, t1, tid="prefetch", cat="io",
                            round=r, bytes=h.nbytes)
            return d

        pool = ThreadPoolExecutor(max_workers=1) if use_async else None
        try:
            nxt = pool.submit(fetch, 0) if use_async else None
            for r in range(rounds):
                if use_async:
                    t0 = time.perf_counter()
                    blk = nxt.result()
                    t1 = time.perf_counter()
                    dt = t1 - t0
                    stats.stall_s += dt
                    tracer.complete("stall", t0, t1, tid="rounds",
                                    cat="stall", round=r)
                    if streamed:
                        stats.merge_stall_s += dt
                    if r + 1 < rounds:
                        # Safe to overlap with round r's writeback: rounds
                        # touch disjoint context rows.
                        nxt = pool.submit(fetch, r + 1)
                        if streamed:
                            # This swap-in runs while round r's compute is
                            # in flight — the measurable merge/read overlap.
                            stats.merge_prefetch_events += 1
                else:
                    t0 = time.perf_counter()
                    blk = fetch(r)
                    t1 = time.perf_counter()
                    stats.stall_s += t1 - t0
                    tracer.complete("stall", t0, t1, tid="rounds",
                                    cat="stall", round=r)

                t0 = time.perf_counter()
                out = body(jnp.int32(base + r * k), blk)   # async dispatch
                out_h = np.asarray(out)                    # blocks on compute
                t1 = time.perf_counter()
                stats.compute_s += t1 - t0
                tracer.complete("compute", t0, t1, tid="rounds",
                                cat="compute", round=r)

                t0 = time.perf_counter()
                r0 = base + r * k
                bk.write_block(r0, r0 + k, out_h, cols=out_idx,
                               wait=not async_writeback)
                led.add_tier_out(out_h.nbytes, disk)
                t1 = time.perf_counter()
                stats.swap_out_s += t1 - t0
                tracer.complete("swap_out", t0, t1, tid="rounds", cat="io",
                                round=r, bytes=out_h.nbytes)
                stats.rounds += 1
                if self.cursors and p < len(self.cursors):
                    # Advisory progress note (atomic, not fsynced): a resume
                    # restarts the whole in-progress superstep either way,
                    # but postmortems see how far the round loop got.
                    self.cursors[p].note_round(r)
        finally:
            if pool is not None:
                pool.shutdown(wait=True)
            # Quiesce in-flight engine writebacks before anyone reads the
            # rows back (and so errors surface here, not at a later read).
            shard.drain()

    # ----------------------------------------------------------- round bodies
    def _run_rounds(self, local_data, body, dev):
        cfg = self.cfg
        v_local = local_data.shape[0]
        rounds = v_local // cfg.k
        base = jnp.int32(0) if dev is None else dev.astype(jnp.int32) * v_local

        if cfg.driver == "async" and rounds > 1:
            # Double-buffered: carry the prefetched round; issue the next
            # round's swap-in before computing the current one so the copy
            # can overlap compute.
            def sbody(carry, r):
                data, blk = carry  # blk: prefetched round r
                nxt = lax.dynamic_slice_in_dim(
                    data, (r + 1) % rounds * cfg.k, cfg.k, axis=0
                )
                nxt = jax.lax.optimization_barrier(nxt)
                out = body(base + r * cfg.k, blk)
                data = lax.dynamic_update_slice_in_dim(
                    data, out, r * cfg.k, axis=0
                )
                return (data, nxt), None

            first = lax.dynamic_slice_in_dim(local_data, 0, cfg.k, axis=0)
            (data, _), _ = lax.scan(
                sbody, (local_data, first), jnp.arange(rounds)
            )
            return data

        def sbody(data, r):
            blk = lax.dynamic_slice_in_dim(data, r * cfg.k, cfg.k, axis=0)
            out = body(base + r * cfg.k, blk)
            data = lax.dynamic_update_slice_in_dim(data, out, r * cfg.k, axis=0)
            return data, None

        data, _ = lax.scan(sbody, local_data, jnp.arange(rounds))
        return data

    def _round_body_full(self, fn):
        lo = self.layout

        def body(rho0, blk):  # blk: [k, words]
            rhos = rho0 + jnp.arange(self.cfg.k, dtype=jnp.int32)
            return jax.vmap(
                lambda rho, w: fn(rho, Ctx(lo, w)).words
            )(rhos, blk)

        return body

    def _round_body_sliced(self, fn, reads: List[str], writes: List[str]):
        lo = self.layout

        # One precomputed word-index map per declaration set: the union of
        # the declared fields' word ranges, sorted so the gather/scatter is a
        # monotone sweep over the context.  A superstep that declares many
        # fields (PSRS declares up to 3 reads + 3 writes) then costs one
        # take + one scatter per round instead of O(fields) slice ops.
        read_idx = jnp.asarray(field_word_index(lo, reads), jnp.int32)
        write_idx = jnp.asarray(field_word_index(lo, writes), jnp.int32)

        def body(rho0, blk):
            rhos = rho0 + jnp.arange(self.cfg.k, dtype=jnp.int32)

            def one(rho, w):
                # Only the declared read fields are "swapped in"; the rest of
                # the context view is zero-filled (reading undeclared fields
                # is an application bug, as with real mmap-backed paging the
                # bytes simply would not be resident).
                ctx_words = jnp.zeros_like(w).at[read_idx].set(
                    w.take(read_idx), indices_are_sorted=True,
                    unique_indices=True,
                )
                out = fn(rho, Ctx(lo, ctx_words))
                # Only declared writes land back in the store.
                return w.at[write_idx].set(
                    out.words.take(write_idx), indices_are_sorted=True,
                    unique_indices=True,
                )

            return jax.vmap(one)(rhos, blk)

        return body

    # ---------------------------------------------------------------- ledger
    def _ledger_superstep(self, sliced, reads, writes, procs=None):
        cfg, lo = self.cfg, self.layout
        B = cfg.block_bytes
        if sliced:
            rbytes = sum(lo.field_bytes(n) for n in reads)
            wbytes = sum(lo.field_bytes(n) for n in writes)
        else:
            rbytes = wbytes = lo.live_bytes
        # Every VP swaps in its (touched) context and swaps it back out once
        # per virtual superstep (§6.1: a careful implementation swaps each
        # context in and out exactly once).  A procs-restricted (recovery)
        # run only swaps the listed shards' contexts.
        nctx = cfg.v if procs is None else len(procs) * cfg.v_local
        self.ledger.add_swap_in(rbytes * nctx, B)
        self.ledger.add_swap_out(wbytes * nctx, B)
        self.ledger.add_barrier()

    # ------------------------------------------------------- debugging helper
    def all_rhos(self) -> jnp.ndarray:
        return jnp.arange(self.cfg.v, dtype=jnp.int32)


# Bind collective methods (defined in their own module to keep files focused).
from . import collectives as _collectives  # noqa: E402

Pems.alltoallv = _collectives.alltoallv
Pems.bcast = _collectives.bcast
Pems.gather = _collectives.gather
Pems.reduce = _collectives.reduce
Pems.allreduce = _collectives.allreduce
Pems.allgather = _collectives.allgather
