"""Tiered backing stores: the external memory made real.

The seed :class:`~repro.core.context.ContextStore` keeps all ``v`` contexts in
one device-resident array — "external memory" is a simulation of itself.  This
module adds the real thing: a backing tier that holds the full ``[v, words]``
population in host RAM (``tier="host"``), in an ``np.memmap``-backed file
(``tier="memmap"``), or behind the :mod:`repro.io` asynchronous file engine
(``tier="file"`` — pread/pwrite submission queues over a ``buffered``,
``odirect``, or ``mmap`` driver), while only the current round's ``P·k``
contexts are ever resident on the device.  The executor's round loop becomes a
host-driven pipeline over this tier (see ``executor._run_tiered``), with the
``async`` driver double-buffering swap-ins on a prefetch thread — and, on the
``file`` tier, leaving writebacks in flight on the engine so reads and writes
genuinely overlap compute (the STXXL-file driver of the thesis, §5.1) — and
with only *live* allocator bytes moving (§6.6).

Every backing exposes the same block API (``read_block``/``write_block`` over
a row range with an optional column selection, plus ``drain``/``flush``), so
the executor and the host-side collectives are tier-agnostic.

Tier selection is per-:class:`~repro.core.executor.PemsConfig` (default
``"device"``: the seed path, byte-for-byte untouched).  All tiers are
bit-identical: the round bodies trace the exact same JAX computation, and the
host-side collectives are pure data movement.
"""

from __future__ import annotations

import os
import tempfile
import weakref
from typing import List, Optional, Tuple

import numpy as np

from repro.io import IOEngine, ensure_file_size, open_file
from repro.io.checksum import ChecksumSidecar, span_plan
from repro.io.faults import split_shard_clause

from .context import ContextLayout, WORD

TIERS = ("device", "host", "memmap", "file")


def shard_row_ranges(m: int, r0: int, r1: int):
    """Split the global row range ``[r0, r1)`` at ``m``-row shard boundaries.

    Yields ``(p, a, b)`` per overlapped shard ``p`` with ``[a, b)`` the
    global sub-range it owns — the one row-addressing convention shared by
    :class:`ShardedBacking`, the executor's per-shard ledger accounting, and
    the tiered collectives."""
    for p in range(r0 // m, (r1 - 1) // m + 1):
        yield p, max(r0, p * m), min(r1, (p + 1) * m)


def _np_dtype(dtype) -> np.dtype:
    return np.dtype(dtype)


def _cols_runs(cols, words: int) -> Tuple[List[Tuple[int, int, int]], int]:
    """Normalise a column selection into contiguous word runs.

    Returns ``(runs, n)`` where each run is ``(out_start, word_start,
    nwords)`` — ``out_start`` indexing the packed destination, ``word_start``
    the context row — and ``n`` is the packed width.  ``cols`` may be
    ``None`` (everything), a unit-step slice, or a sorted word-index array
    (the executor's live/sliced index maps).
    """
    if cols is None:
        return [(0, 0, words)], words
    if isinstance(cols, slice):
        start, stop, step = cols.indices(words)
        if step != 1:
            raise ValueError("column slices must be unit-step")
        return [(0, start, stop - start)], stop - start
    idx = np.asarray(cols)
    n = int(idx.size)
    if n == 0:
        return [], 0
    breaks = np.flatnonzero(np.diff(idx) != 1) + 1
    starts = np.concatenate([[0], breaks])
    ends = np.concatenate([breaks, [n]])
    return [(int(s), int(idx[s]), int(e - s))
            for s, e in zip(starts, ends)], n


class _ArrayBacking:
    """Shared block API for backings that expose a ``[v, words]`` ndarray."""

    arr: np.ndarray
    checksum: Optional[ChecksumSidecar] = None

    def read_block(self, r0: int, r1: int, cols=None) -> np.ndarray:
        """Rows ``[r0, r1)`` with the selected columns, as a contiguous
        uint32 host copy."""
        rows = self.arr[r0:r1]
        return np.ascontiguousarray(rows if cols is None else rows[:, cols])

    def write_block(self, r0: int, r1: int, value, cols=None,
                    wait: bool = True) -> None:
        """Write rows ``[r0, r1)``; ``value`` may broadcast along rows.
        ``wait`` exists for engine-backed tiers (here writes are
        synchronous)."""
        if cols is None:
            self.arr[r0:r1] = value
        else:
            self.arr[r0:r1, cols] = value

    def drain(self) -> None:
        pass


class HostBacking(_ArrayBacking):
    """Backing tier in plain host RAM: a ``[v, words]`` uint32 ndarray.

    Stands in for pinned host memory — on CPU backends it *is* the fastest
    possible tier; on accelerators it models the host side of the PCIe swap.
    """

    tier = "host"
    disk = False
    path: Optional[str] = None

    def __init__(self, v: int, words: int):
        self.v = v
        self.words = words
        self.arr = np.zeros((v, words), np.uint32)

    @property
    def nbytes(self) -> int:
        return self.arr.nbytes

    def flush(self) -> None:  # symmetry with the disk backings
        pass


class MemmapBacking(_ArrayBacking):
    """Backing tier on disk: ``np.memmap`` over a (sparse) backing file.

    The file is created sparse at exactly ``v·μ`` bytes — the PEMS2 disk
    requirement (§6.3) — so untouched ranges cost no real disk blocks until
    the swap engine writes them.  A caller-provided ``path`` has
    create-or-reuse semantics: an existing file's contents are preserved
    (only extended when too small), so resuming from a populated backing
    file never zeroes it.  When no ``path`` is given a temporary file is
    created and unlinked when the backing is garbage-collected.
    """

    tier = "memmap"
    disk = True

    def __init__(self, v: int, words: int, path: Optional[str] = None,
                 checksum: bool = False):
        owns = path is None
        if path is None:
            fd, path = tempfile.mkstemp(prefix="pems_ctx_", suffix=".bin")
            os.close(fd)
        self.path = path
        self.v = v
        self.words = words
        self.rowbytes = words * WORD
        existed = os.path.exists(path) and os.path.getsize(path) > 0
        ensure_file_size(path, v * words * WORD)   # sparse; never truncates
        self.arr = np.memmap(path, dtype=np.uint32, mode="r+",
                             shape=(v, words))
        self.checksum = None
        if checksum:
            self.checksum = ChecksumSidecar(path, v, self.rowbytes)
            if self.checksum.fresh:
                if existed:        # adopt pre-existing data as-is
                    self.recompute_checksums()
                else:              # fresh sparse file reads as zeros
                    self.checksum.seed_zero()
        if owns:
            self._finalizer = weakref.finalize(self, _unlink_quiet, path)
            weakref.finalize(self, _unlink_quiet, path + ".crc")

    @property
    def nbytes(self) -> int:
        return self.arr.nbytes

    def flush(self) -> None:
        self.arr.flush()
        if self.checksum is not None:
            self.checksum.flush()

    # -------------------------------------------------------------- integrity
    def _rows_u8(self) -> np.ndarray:
        return self.arr.view(np.uint8)

    def _spans(self, cols):
        cs = self.checksum
        if cols is None:
            return [(0, cs.nseg - 1, [])]
        runs, _ = _cols_runs(cols, self.words)
        ranges = [(w0 * WORD, (w0 + nw) * WORD) for _, w0, nw in runs]
        return span_plan(ranges, cs.chk, self.rowbytes)

    def read_block(self, r0: int, r1: int, cols=None) -> np.ndarray:
        if self.checksum is not None:
            cs, rb = self.checksum, self._rows_u8()
            for s0, s1, _ in self._spans(cols):
                b0 = s0 * cs.chk
                b1 = min(self.rowbytes, (s1 + 1) * cs.chk)
                for i in range(r0, r1):
                    cs.verify_span(i, s0, rb[i, b0:b1])
        return super().read_block(r0, r1, cols)

    def write_block(self, r0: int, r1: int, value, cols=None,
                    wait: bool = True) -> None:
        if self.checksum is None:
            return super().write_block(r0, r1, value, cols, wait)
        cs, rb = self.checksum, self._rows_u8()
        spans = self._spans(cols)
        # Verify partially-covered boundary segments *before* folding them
        # into fresh checksums — a torn block must never be blessed.
        for s0, s1, partial in spans:
            for s in partial:
                b0, b1 = cs.seg_bounds(s)
                for i in range(r0, r1):
                    cs.verify_span(i, s, rb[i, b0:b1])
        super().write_block(r0, r1, value, cols, wait)
        for s0, s1, _ in spans:
            b0 = s0 * cs.chk
            b1 = min(self.rowbytes, (s1 + 1) * cs.chk)
            for i in range(r0, r1):
                cs.set_span(i, s0, rb[i, b0:b1])

    def recompute_checksums(self) -> None:
        """Re-bless every row's CRCs from the bytes on disk (recovery: after
        a crash the sidecar may record intended-but-torn writes for rows the
        resume is about to regenerate anyway)."""
        if self.checksum is None:
            return
        self.checksum.set_rows(0, self._rows_u8())
        self.checksum.flush()
        self.checksum.fresh = False


class FileBacking:
    """Backing tier behind the :mod:`repro.io` engine: the ``[v, words]``
    population lives in a plain file reached only through positional
    pread/pwrite submissions — no page-cache mapping of the store (unless
    the ``mmap`` adapter driver is chosen), so with the ``odirect`` driver
    the measured swap traffic is genuinely cold storage.

    Reads/writes decompose into contiguous byte runs (whole row blocks for
    full swaps; per-row field runs for sliced/live column selections) and
    ride the engine's bounded submission queue — ``io_queue_depth`` requests
    in flight, overlapped by the worker pool.  ``write_block(wait=False)``
    leaves the writeback in flight: the executor's async driver uses this so
    round ``r-1``'s swap-out and round ``r+1``'s swap-in overlap round
    ``r``'s compute in *both* directions.
    """

    tier = "file"
    disk = True

    # Contiguous spans are split into requests of this size so a single big
    # swap still exercises (and benefits from) the submission queue.
    chunk_bytes = 1 << 20

    def __init__(self, v: int, words: int, path: Optional[str] = None,
                 io_driver: str = "buffered", io_queue_depth: int = 8,
                 stats=None, ledger=None, checksum: bool = False,
                 fault_spec: Optional[str] = None, io_retries: int = 2,
                 io_backoff_s: float = 0.002):
        owns = path is None
        if path is None:
            fd, path = tempfile.mkstemp(prefix="pems_ctx_", suffix=".bin")
            os.close(fd)
        self.path = path
        self.v = v
        self.words = words
        self.rowbytes = words * WORD
        self.io_driver = io_driver
        existed = os.path.exists(path) and os.path.getsize(path) > 0
        self.file = open_file(path, v * words * WORD, io_driver,
                              fault_spec=fault_spec)
        self.engine = IOEngine(self.file, queue_depth=io_queue_depth,
                               stats=stats, ledger=ledger,
                               retries=io_retries, backoff_s=io_backoff_s)
        self.checksum = None
        if checksum:
            self.checksum = ChecksumSidecar(path, v, self.rowbytes)
            if self.checksum.fresh:
                if existed:        # adopt pre-existing data as-is
                    self.recompute_checksums()
                else:              # fresh sparse file reads as zeros
                    self.checksum.seed_zero()
        self._finalizer = weakref.finalize(
            self, _close_quiet, self.engine, path if owns else None)

    @property
    def nbytes(self) -> int:
        return self.v * self.rowbytes

    def _whole_rows_cheaper(self, runs) -> bool:
        """On an aligned driver (odirect) every per-row run widens to at
        least one whole block per direction, and sub-block rows share
        blocks (serialised RMW).  When whole rows cost no more than the
        per-run aligned requests would, move whole rows instead: one
        contiguous chunked transfer, no boundary conflicts."""
        align = self.file.align
        return align > 1 and bool(runs) and self.rowbytes <= len(runs) * align

    # ------------------------------------------------------------- block API
    def read_block(self, r0: int, r1: int, cols=None) -> np.ndarray:
        runs, n = _cols_runs(cols, self.words)
        rows = r1 - r0
        if cols is not None and self._whole_rows_cheaper(runs):
            whole = self.read_block(r0, r1, None)     # verified if checksummed
            out = np.empty((rows, n), np.uint32)
            for j, w0, nw in runs:
                out[:, j:j + nw] = whole[:, w0:w0 + nw]
            return out
        if cols is None:
            out = self._read_rows(r0, r1)
            if self.checksum is not None:
                self.checksum.verify_rows(r0, out.view(np.uint8))
            return out
        if self.checksum is not None:
            return self._read_cols_checksummed(r0, r1, runs, n)
        out = np.empty((rows, n), np.uint32)
        reqs = []
        for i in range(rows):
            base = (r0 + i) * self.rowbytes
            for j, w0, nw in runs:
                reqs.append(self.engine.submit_read(
                    base + w0 * WORD, out[i, j:j + nw].view(np.uint8)))
        self.engine.wait(reqs)
        return out

    def _read_rows(self, r0: int, r1: int) -> np.ndarray:
        """Whole rows ``[r0, r1)`` as chunked engine reads — no verification
        (``read_block`` verifies; ``recompute_checksums`` must not)."""
        rows = r1 - r0
        out = np.empty((rows, self.words), np.uint32)
        flat = out.reshape(-1).view(np.uint8)
        base = r0 * self.rowbytes
        total = rows * self.rowbytes
        reqs = []
        for o in range(0, total, self.chunk_bytes):
            nb = min(self.chunk_bytes, total - o)
            reqs.append(self.engine.submit_read(base + o, flat[o:o + nb]))
        self.engine.wait(reqs)
        return out

    def _read_cols_checksummed(self, r0: int, r1: int, runs, n) -> np.ndarray:
        """Column-run reads widened to checksum-segment boundaries so every
        returned byte is covered by a verified segment."""
        cs = self.checksum
        rows = r1 - r0
        out = np.empty((rows, n), np.uint32)
        ranges = [(w0 * WORD, (w0 + nw) * WORD) for _, w0, nw in runs]
        spans = span_plan(ranges, cs.chk, self.rowbytes)
        reqs, bufs = [], []
        for i in range(rows):
            base = (r0 + i) * self.rowbytes
            for s0, s1, _ in spans:
                b0 = s0 * cs.chk
                b1 = min(self.rowbytes, (s1 + 1) * cs.chk)
                scr = np.empty(b1 - b0, np.uint8)
                reqs.append(self.engine.submit_read(base + b0, scr))
                bufs.append((i, s0, b0, scr))
        self.engine.wait(reqs)
        for i, s0, b0, scr in bufs:
            cs.verify_span(r0 + i, s0, scr)
            hi = b0 + len(scr)
            for j, w0, nw in runs:
                rb0, rb1 = w0 * WORD, (w0 + nw) * WORD
                lo2, hi2 = max(rb0, b0), min(rb1, hi)
                if lo2 < hi2:
                    src = scr[lo2 - b0:hi2 - b0].view(np.uint32)
                    o0 = j + (lo2 - rb0) // WORD
                    out[i, o0:o0 + src.size] = src
        return out

    def write_block(self, r0: int, r1: int, value, cols=None,
                    wait: bool = True) -> None:
        runs, n = _cols_runs(cols, self.words)
        rows = r1 - r0
        value = np.broadcast_to(np.asarray(value), (rows, n))
        if cols is not None and self._whole_rows_cheaper(runs):
            # Read-modify-write whole rows: cheaper than per-run aligned
            # RMW on every row, and immune to shared-boundary-block
            # serialisation.  Callers never write the same rows
            # concurrently (rounds/collectives touch disjoint row ranges).
            whole = self.read_block(r0, r1, None)
            for j, w0, nw in runs:
                whole[:, w0:w0 + nw] = value[:, j:j + nw]
            self.write_block(r0, r1, whole, None, wait=wait)
            return
        # Fire-and-forget writebacks auto-reap their completions (errors
        # still surface at the superstep's drain); waited writes are reaped
        # by wait() itself.  Either way the completion list stays bounded.
        if cols is not None and self.checksum is not None:
            self._write_cols_checksummed(r0, r1, value, runs, n, wait)
            return
        reqs = []
        if cols is None:
            buf = np.ascontiguousarray(value)
            if self.checksum is not None:
                # Record the *intended* CRCs at submission: a write that
                # dies midway leaves a detectable mismatch behind.
                self.checksum.set_rows(r0, buf.view(np.uint8))
            flat = buf.reshape(-1).view(np.uint8)
            base = r0 * self.rowbytes
            total = rows * self.rowbytes
            for o in range(0, total, self.chunk_bytes):
                nb = min(self.chunk_bytes, total - o)
                reqs.append(self.engine.submit_write(
                    base + o, flat[o:o + nb], auto_reap=not wait))
        else:
            for i in range(rows):
                base = (r0 + i) * self.rowbytes
                for j, w0, nw in runs:
                    reqs.append(self.engine.submit_write(
                        base + w0 * WORD,
                        np.ascontiguousarray(value[i, j:j + nw]),
                        auto_reap=not wait))
        if wait:
            self.engine.wait(reqs)

    def _write_cols_checksummed(self, r0: int, r1: int, value, runs, n,
                                wait: bool) -> None:
        """Column-run writes at checksum-segment granularity: new bytes come
        from ``value``; partially-covered boundary segments read (and verify)
        their pre-image first so neighbouring bytes survive with a CRC that
        was never blessed over torn data."""
        cs = self.checksum
        rows = r1 - r0
        vb = np.ascontiguousarray(value).view(np.uint8).reshape(
            rows, n * WORD)
        ranges = [(w0 * WORD, (w0 + nw) * WORD) for _, w0, nw in runs]
        spans = span_plan(ranges, cs.chk, self.rowbytes)
        pre_reqs, items = [], []
        for i in range(rows):
            base = (r0 + i) * self.rowbytes
            for s0, s1, partial in spans:
                b0 = s0 * cs.chk
                b1 = min(self.rowbytes, (s1 + 1) * cs.chk)
                buf = np.empty(b1 - b0, np.uint8)
                for s in partial:
                    p0, p1 = cs.seg_bounds(s)
                    pre_reqs.append(self.engine.submit_read(
                        base + p0, buf[p0 - b0:p1 - b0]))
                items.append((i, s0, b0, buf, partial))
        if pre_reqs:
            self.engine.wait(pre_reqs)
        wreqs = []
        for i, s0, b0, buf, partial in items:
            row = r0 + i
            for s in partial:
                p0, p1 = cs.seg_bounds(s)
                cs.verify_span(row, s, buf[p0 - b0:p1 - b0])
            hi = b0 + len(buf)
            for j, w0, nw in runs:
                rb0, rb1 = w0 * WORD, (w0 + nw) * WORD
                lo2, hi2 = max(rb0, b0), min(rb1, hi)
                if lo2 < hi2:
                    buf[lo2 - b0:hi2 - b0] = vb[
                        i, j * WORD + (lo2 - rb0):j * WORD + (hi2 - rb0)]
            cs.set_span(row, s0, buf)
            wreqs.append(self.engine.submit_write(
                row * self.rowbytes + b0, buf, auto_reap=not wait))
        if wait:
            self.engine.wait(wreqs)

    def recompute_checksums(self) -> None:
        """Re-bless every row's CRCs from the bytes on disk (recovery: after
        a crash the sidecar may record intended-but-torn writes for rows the
        resume is about to regenerate anyway)."""
        if self.checksum is None:
            return
        step = max(1, self.chunk_bytes // self.rowbytes)
        for r in range(0, self.v, step):
            r1 = min(self.v, r + step)
            rows = self._read_rows(r, r1)
            self.checksum.set_rows(r, rows.view(np.uint8))
        self.checksum.flush()
        self.checksum.fresh = False

    def drain(self) -> None:
        self.engine.drain()

    def flush(self) -> None:
        self.engine.fsync()
        if self.checksum is not None:
            self.checksum.flush()

    def close(self) -> None:
        self._finalizer()


def _unlink_quiet(path: str) -> None:
    try:
        os.unlink(path)
    except OSError:
        pass


def _close_quiet(engine, unlink_path: Optional[str]) -> None:
    try:
        engine.close()
    except Exception:
        pass
    if unlink_path is not None:
        _unlink_quiet(unlink_path)
        _unlink_quiet(unlink_path + ".crc")


class ShardedBacking:
    """The parallel disk model (thesis §6.3): ``P`` disjoint ``v/P``-row
    shards, one per mesh process, each a full backing of its own.

    Every shard owns an aligned, non-overlapping row range ``[p·m, (p+1)·m)``
    of the global ``[v, words]`` population, backed by its *own* file
    (``<path>.shard<p>``, or a private temp file when no path is given) —
    and, on ``tier="file"``, its own :class:`~repro.io.IOEngine` + driver
    instance, so P processes genuinely drive P disks with P submission
    queues.  Per-shard ``stats``/``ledger`` objects (``shard_stats``/
    ``shard_ledgers``) receive each shard's measured traffic, making the
    vμ/P-per-disk accounting of the thesis directly observable.

    The block API is the same as every other backing: ``read_block``/
    ``write_block`` accept *global* row ranges and split them at shard
    boundaries (the executor's k-row round blocks never straddle one —
    ``(v/P) % k == 0`` is validated at config time — but collectives'
    whole-population reads do, and are concatenated transparently).

    Fault injection composes with sharding: a ``fault_spec`` carrying a
    ``shard=N`` clause is applied only to shard ``N``'s driver; the other
    shards run the clean inner driver — the single-disk-failure model the
    per-process recovery path is built for.  There is deliberately no
    ``arr`` view of the whole population: cross-shard access must go through
    the block API so per-shard accounting cannot be bypassed.
    """

    def __init__(self, tier: str, v: int, words: int, nshards: int,
                 path: Optional[str] = None, *,
                 io_driver: Optional[str] = None, io_queue_depth: int = 8,
                 shard_stats=None, shard_ledgers=None, checksum: bool = False,
                 fault_spec: Optional[str] = None, io_retries: int = 2,
                 io_backoff_s: float = 0.002):
        if tier not in ("host", "memmap", "file"):
            raise ValueError(f"cannot shard tier {tier!r}")
        if nshards < 1 or v % nshards:
            raise ValueError(
                f"v={v} must divide into nshards={nshards} equal row shards")
        self.tier = tier
        self.v = v
        self.words = words
        self.rowbytes = words * WORD
        self.P = nshards
        self.m = v // nshards
        self.path = path
        target, spec = split_shard_clause(fault_spec)
        if target is not None and target >= nshards:
            raise ValueError(
                f"fault_spec targets shard {target} but only "
                f"{nshards} shards exist")
        self.shards = []
        for p in range(nshards):
            sp = None if path is None else f"{path}.shard{p}"
            drv, fs = io_driver, None
            if "faulty" in (io_driver or "").split(":")[:-1]:
                if target is None or target == p:
                    fs = spec or None
                else:
                    # Healthy shards run without the injector: one disk
                    # fails, the other P-1 never see it at all.  Other
                    # wrappers in the chain (e.g. sanitize:) stay on.
                    drv = ":".join(w for w in io_driver.split(":")
                                   if w != "faulty")
            self.shards.append(make_backing(
                tier, self.m, words, sp, io_driver=drv,
                io_queue_depth=io_queue_depth,
                stats=None if shard_stats is None else shard_stats[p],
                ledger=None if shard_ledgers is None else shard_ledgers[p],
                checksum=checksum, fault_spec=fs,
                io_retries=io_retries, io_backoff_s=io_backoff_s))
            eng = getattr(self.shards[p], "engine", None)
            if eng is not None:
                eng.name = f"shard{p}"
        self.disk = self.shards[0].disk

    @property
    def nbytes(self) -> int:
        return sum(s.nbytes for s in self.shards)

    @property
    def checksum(self):
        """Per-shard sidecars as a tuple, or None when no shard is
        checksummed (truthiness matches the single-backing convention)."""
        cs = tuple(s.checksum for s in self.shards)
        return cs if any(c is not None for c in cs) else None

    # ------------------------------------------------------------- block API
    def read_block(self, r0: int, r1: int, cols=None) -> np.ndarray:
        """Global rows ``[r0, r1)``, concatenated across shard boundaries."""
        parts = [
            self.shards[p].read_block(a - p * self.m, b - p * self.m, cols)
            for p, a, b in shard_row_ranges(self.m, r0, r1)
        ]
        return parts[0] if len(parts) == 1 else np.concatenate(parts, axis=0)

    def write_block(self, r0: int, r1: int, value, cols=None,
                    wait: bool = True) -> None:
        """Write global rows ``[r0, r1)``; ``value`` may broadcast along
        rows (a ``[1, n]`` block lands in every row, as for bcast)."""
        val = np.asarray(value)
        bcast = val.ndim < 2 or val.shape[0] == 1
        for p, a, b in shard_row_ranges(self.m, r0, r1):
            sub = val if bcast else val[a - r0:b - r0]
            self.shards[p].write_block(a - p * self.m, b - p * self.m, sub,
                                       cols, wait=wait)

    def drain(self) -> None:
        for s in self.shards:
            s.drain()

    def drain_shard(self, p: int) -> None:
        self.shards[p].drain()

    def flush(self) -> None:
        for s in self.shards:
            s.flush()

    def flush_shard(self, p: int) -> None:
        """Durability for one shard only — the per-process recovery commit
        (a stage run with ``procs=[p]`` writes nothing outside shard p)."""
        self.shards[p].flush()

    def recompute_checksums(self, shard: Optional[int] = None) -> None:
        """Re-bless CRCs from the bytes on disk — all shards, or just one
        (per-process recovery touches only the failed shard's sidecar)."""
        targets = self.shards if shard is None else [self.shards[shard]]
        for s in targets:
            if s.checksum is not None:
                s.recompute_checksums()

    def close(self) -> None:
        for s in self.shards:
            close = getattr(s, "close", None)
            if close is not None:
                close()


def make_backing(tier: str, v: int, words: int,
                 path: Optional[str] = None, *,
                 P: int = 1,
                 io_driver: Optional[str] = None, io_queue_depth: int = 8,
                 stats=None, ledger=None,
                 shard_stats=None, shard_ledgers=None,
                 checksum: bool = False,
                 fault_spec: Optional[str] = None, io_retries: int = 2,
                 io_backoff_s: float = 2e-3):
    """Construct a backing for ``v`` rows of ``words`` uint32 words.

    ``P > 1`` returns a :class:`ShardedBacking` — one inner backing (and on
    the file tier one engine) per process, billing ``shard_stats[p]`` /
    ``shard_ledgers[p]``.  ``P == 1`` returns the plain single backing,
    billing ``stats``/``ledger``; a leading ``shard=`` clause in
    ``fault_spec`` is stripped (there is only one shard to target)."""
    if tier == "device":
        raise ValueError("tier='device' has no backing store")
    if P > 1:
        return ShardedBacking(tier, v, words, P, path,
                              io_driver=io_driver,
                              io_queue_depth=io_queue_depth,
                              shard_stats=shard_stats,
                              shard_ledgers=shard_ledgers,
                              checksum=checksum, fault_spec=fault_spec,
                              io_retries=io_retries,
                              io_backoff_s=io_backoff_s)
    _, fault_spec = split_shard_clause(fault_spec)
    if tier == "host":
        return HostBacking(v, words)
    if tier == "memmap":
        return MemmapBacking(v, words, path, checksum=checksum)
    if tier == "file":
        return FileBacking(v, words, path,
                           io_driver=io_driver or "buffered",
                           io_queue_depth=io_queue_depth,
                           stats=stats, ledger=ledger, checksum=checksum,
                           fault_spec=fault_spec or None,
                           io_retries=io_retries,
                           io_backoff_s=io_backoff_s)
    raise ValueError(f"unknown backing tier {tier!r} (choose from {TIERS})")


class TieredStore:
    """Host/disk-resident context store with the :class:`ContextStore` field
    API.

    Unlike the functional device store, a TieredStore mutates its backing in
    place and returns ``self`` — once the population no longer fits on the
    device, swap economics beat functional purity, and in-place update is
    exactly the thesis' disk model.  Call sites written for the device store
    (``store = pems.superstep(store, ...)``) work unchanged.

    When constructed with a ``ledger`` (the executor always passes its own),
    every ``field``/``with_field`` on a disk-resident backing (``memmap``
    and ``file`` alike) records the measured disk traffic — one count per
    physical access, including the initial data load; callers touching the
    backing's block API directly account for themselves.  Under a
    :class:`ShardedBacking` pass ``shard_ledgers`` as well: field traffic is
    then split at shard boundaries and billed to the owning shard's ledger,
    so per-shard ``disk_read/write_bytes`` sum to the ``P == 1`` totals.
    """

    def __init__(self, layout: ContextLayout, backing, ledger=None,
                 shard_ledgers=None):
        self.layout = layout
        self.backing = backing
        self.ledger = ledger
        self.shard_ledgers = shard_ledgers

    # convenience -----------------------------------------------------------
    @property
    def tier(self) -> str:
        return self.backing.tier

    @property
    def on_disk(self) -> bool:
        """Whether field traffic is physical disk traffic (ledger-counted)."""
        return self.backing.disk

    @property
    def data(self) -> np.ndarray:
        """The full ``[v, words]`` uint32 population (host/disk resident).
        Only array-addressable tiers (host/memmap) expose it; the ``file``
        tier is reached through the block API."""
        return self.backing.arr

    @property
    def v(self) -> int:
        return self.backing.v

    @property
    def mu_bytes(self) -> int:
        return self.layout.mu_bytes

    # accounting ------------------------------------------------------------
    def _account(self, r0: int, r1: int, row_bytes: int, write: bool) -> None:
        """Bill ``(r1-r0)·row_bytes`` of field traffic to the owning
        ledger(s): the single ledger at ``P == 1``; split at shard
        boundaries to ``shard_ledgers[p]`` under a sharded backing."""
        if not self.on_disk:
            return
        if self.shard_ledgers is not None and hasattr(self.backing, "m"):
            for p, a, b in shard_row_ranges(self.backing.m, r0, r1):
                led = self.shard_ledgers[p]
                if led is not None:
                    (led.add_disk_write if write
                     else led.add_disk_read)((b - a) * row_bytes)
            return
        if self.ledger is not None:
            (self.ledger.add_disk_write if write
             else self.ledger.add_disk_read)((r1 - r0) * row_bytes)

    # field access ----------------------------------------------------------
    def field(self, name: str) -> np.ndarray:
        """Gather a field across all contexts → ``[v, *shape]`` (a host copy,
        matching the device store's functional reads)."""
        return self.field_rows(name, 0, self.v)

    def field_rows(self, name: str, r0: int, r1: int) -> np.ndarray:
        """Gather a field for contexts ``[r0, r1)`` only → ``[r1-r0, *shape]``
        — the per-process collectives read one shard's rows this way."""
        off = self.layout.offset(name)
        f = self.layout.field(name)
        w = self.backing.read_block(r0, r1, cols=slice(off, off + f.words))
        self._account(r0, r1, f.words * WORD, write=False)
        return w.view(_np_dtype(f.dtype)).reshape((r1 - r0,) + f.shape)

    def with_field(self, name: str, value) -> "TieredStore":
        """Write a field across all contexts (in place; returns ``self``)."""
        return self.with_field_rows(name, 0, value, rows=self.v)

    def with_field_rows(self, name: str, r0: int, value,
                        rows: Optional[int] = None) -> "TieredStore":
        """Write a field for contexts ``[r0, r0+rows)`` (in place; returns
        ``self``).  ``rows`` defaults to ``value``'s leading dimension."""
        off = self.layout.offset(name)
        f = self.layout.field(name)
        value = np.asarray(value)
        if value.dtype != _np_dtype(f.dtype):
            value = value.astype(_np_dtype(f.dtype))
        if rows is None:
            rows = value.reshape(-1, f.words).shape[0] if f.words else 0
        w = np.ascontiguousarray(value).reshape(rows, f.words)
        self.backing.write_block(r0, r0 + rows, w.view(np.uint32),
                                 cols=slice(off, off + f.words))
        self._account(r0, r0 + rows, f.words * WORD, write=True)
        return self

    def field_bytes(self, name: str) -> int:
        return self.layout.field_bytes(name)

    def flush(self) -> None:
        self.backing.flush()
