"""Tiered backing stores: the external memory made real.

The seed :class:`~repro.core.context.ContextStore` keeps all ``v`` contexts in
one device-resident array — "external memory" is a simulation of itself.  This
module adds the real thing: a backing tier that holds the full ``[v, words]``
population in host RAM (``tier="host"``) or in an ``np.memmap``-backed file on
disk (``tier="memmap"``), while only the current round's ``P·k`` contexts are
ever resident on the device.  The executor's round loop becomes a host-driven
pipeline over this tier (see ``executor._run_tiered``), with the ``async``
driver double-buffering swap-ins on a prefetch thread so disk I/O overlaps
compute — the STXXL-file driver of the thesis (§5.1) — and with only *live*
allocator bytes moving (§6.6).

Tier selection is per-:class:`~repro.core.executor.PemsConfig` (default
``"device"``: the seed path, byte-for-byte untouched).  All tiers are
bit-identical: the round bodies trace the exact same JAX computation, and the
host-side collectives are pure data movement.
"""

from __future__ import annotations

import os
import tempfile
import weakref
from typing import Optional

import numpy as np

from .context import ContextLayout, WORD

TIERS = ("device", "host", "memmap")


def _np_dtype(dtype) -> np.dtype:
    return np.dtype(dtype)


class HostBacking:
    """Backing tier in plain host RAM: a ``[v, words]`` uint32 ndarray.

    Stands in for pinned host memory — on CPU backends it *is* the fastest
    possible tier; on accelerators it models the host side of the PCIe swap.
    """

    tier = "host"
    path: Optional[str] = None

    def __init__(self, v: int, words: int):
        self.arr = np.zeros((v, words), np.uint32)

    @property
    def nbytes(self) -> int:
        return self.arr.nbytes

    def flush(self) -> None:  # symmetry with MemmapBacking
        pass


class MemmapBacking:
    """Backing tier on disk: ``np.memmap`` over a (sparse) backing file.

    The file is created sparse at exactly ``v·μ`` bytes — the PEMS2 disk
    requirement (§6.3) — so untouched ranges cost no real disk blocks until
    the swap engine writes them.  When no ``path`` is given a temporary file
    is created and unlinked when the backing is garbage-collected.
    """

    tier = "memmap"

    def __init__(self, v: int, words: int, path: Optional[str] = None):
        owns = path is None
        if path is None:
            fd, path = tempfile.mkstemp(prefix="pems_ctx_", suffix=".bin")
            os.close(fd)
        self.path = path
        with open(path, "wb") as f:
            f.truncate(v * words * WORD)   # sparse: no blocks allocated yet
        self.arr = np.memmap(path, dtype=np.uint32, mode="r+",
                             shape=(v, words))
        if owns:
            self._finalizer = weakref.finalize(self, _unlink_quiet, path)

    @property
    def nbytes(self) -> int:
        return self.arr.nbytes

    def flush(self) -> None:
        self.arr.flush()


def _unlink_quiet(path: str) -> None:
    try:
        os.unlink(path)
    except OSError:
        pass


def make_backing(tier: str, v: int, words: int,
                 path: Optional[str] = None):
    if tier == "host":
        return HostBacking(v, words)
    if tier == "memmap":
        return MemmapBacking(v, words, path)
    raise ValueError(f"unknown backing tier {tier!r} (choose from {TIERS})")


class TieredStore:
    """Host/disk-resident context store with the :class:`ContextStore` field
    API.

    Unlike the functional device store, a TieredStore mutates its backing in
    place and returns ``self`` — once the population no longer fits on the
    device, swap economics beat functional purity, and in-place update is
    exactly the thesis' disk model.  Call sites written for the device store
    (``store = pems.superstep(store, ...)``) work unchanged.

    When constructed with a ``ledger`` (the executor always passes its own),
    every ``field``/``with_field`` on the memmap tier records the measured
    disk traffic — one count per physical access, including the initial data
    load; callers touching ``backing.arr`` directly account for themselves.
    """

    def __init__(self, layout: ContextLayout, backing, ledger=None):
        self.layout = layout
        self.backing = backing
        self.ledger = ledger

    # convenience -----------------------------------------------------------
    @property
    def tier(self) -> str:
        return self.backing.tier

    @property
    def data(self) -> np.ndarray:
        """The full ``[v, words]`` uint32 population (host/disk resident)."""
        return self.backing.arr

    @property
    def v(self) -> int:
        return self.backing.arr.shape[0]

    @property
    def mu_bytes(self) -> int:
        return self.layout.mu_bytes

    # field access ----------------------------------------------------------
    def field(self, name: str) -> np.ndarray:
        """Gather a field across all contexts → ``[v, *shape]`` (a host copy,
        matching the device store's functional reads)."""
        off = self.layout.offset(name)
        f = self.layout.field(name)
        w = np.ascontiguousarray(self.backing.arr[:, off:off + f.words])
        if self.ledger is not None and self.tier == "memmap":
            self.ledger.add_disk_read(w.nbytes)
        return w.view(_np_dtype(f.dtype)).reshape((self.v,) + f.shape)

    def with_field(self, name: str, value) -> "TieredStore":
        """Write a field across all contexts (in place; returns ``self``)."""
        off = self.layout.offset(name)
        f = self.layout.field(name)
        value = np.asarray(value)
        if value.dtype != _np_dtype(f.dtype):
            value = value.astype(_np_dtype(f.dtype))
        w = np.ascontiguousarray(value).reshape(self.v, f.words)
        self.backing.arr[:, off:off + f.words] = w.view(np.uint32)
        if self.ledger is not None and self.tier == "memmap":
            self.ledger.add_disk_write(w.nbytes)
        return self

    def field_bytes(self, name: str) -> int:
        return self.layout.field_bytes(name)

    def flush(self) -> None:
        self.backing.flush()
