"""Trace-time I/O ledger for the PEMS2 simulation.

The thesis measures algorithms by *I/O volume* (bytes moved between RAM and
external memory) and *number of I/O operations* (block transfers).  Both are
statically determined by the simulation parameters (v, P, k, mu, omega, B) and
the deterministic ID-ordered round schedule (thesis §6.5), so the ledger is a
pure-Python event counter updated at trace time.  Tests assert that the ledger
reproduces the thesis' closed forms (``repro.core.analysis``) exactly.

Byte categories mirror the thesis' cost terms:

* ``swap_in`` / ``swap_out``      — context swapping (the ``S`` coefficient)
* ``msg_direct``                  — messages delivered directly to a context on
                                    disk (PEMS2, §6.2)
* ``msg_indirect``                — messages staged through the indirect area
                                    (PEMS1, §2.2) or re-read for late delivery
* ``boundary``                    — boundary-block cache flushes (§6.2)
* ``network``                     — bytes crossing the real-processor network
                                    (the ``g`` coefficient)
* ``disk_space``                  — peak external-memory footprint (§6.3)
"""

from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass
class IOLedger:
    """Byte counters for one simulated program run."""

    swap_in: int = 0
    swap_out: int = 0
    msg_direct: int = 0
    msg_indirect: int = 0
    boundary: int = 0
    network: int = 0
    disk_space: int = 0
    num_ios: int = 0          # block-granular I/O operations
    supersteps: int = 0       # internal superstep barriers (the ``L`` term)

    # ------------------------------------------------------------------ totals
    @property
    def swap_total(self) -> int:
        return self.swap_in + self.swap_out

    @property
    def message_total(self) -> int:
        return self.msg_direct + self.msg_indirect + self.boundary

    @property
    def io_total(self) -> int:
        """Total external-memory traffic (the thesis' "I/O volume")."""
        return self.swap_total + self.message_total

    # ------------------------------------------------------------------ events
    def add_swap_in(self, nbytes: int, block: int) -> None:
        self.swap_in += nbytes
        self.num_ios += _blocks(nbytes, block)

    def add_swap_out(self, nbytes: int, block: int) -> None:
        self.swap_out += nbytes
        self.num_ios += _blocks(nbytes, block)

    def add_msg_direct(self, nbytes: int, block: int) -> None:
        self.msg_direct += nbytes
        self.num_ios += _blocks(nbytes, block)

    def add_msg_indirect(self, nbytes: int, block: int) -> None:
        self.msg_indirect += nbytes
        self.num_ios += _blocks(nbytes, block)

    def add_boundary(self, nbytes: int, block: int) -> None:
        self.boundary += nbytes
        self.num_ios += _blocks(nbytes, block)

    def add_network(self, nbytes: int) -> None:
        self.network += nbytes

    def add_barrier(self, n: int = 1) -> None:
        self.supersteps += n

    def require_disk(self, nbytes: int) -> None:
        self.disk_space = max(self.disk_space, nbytes)

    # ---------------------------------------------------------------- reporting
    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self) | {
            "swap_total": self.swap_total,
            "message_total": self.message_total,
            "io_total": self.io_total,
        }

    def merge(self, other: "IOLedger") -> "IOLedger":
        out = IOLedger()
        for f in dataclasses.fields(IOLedger):
            setattr(out, f.name, getattr(self, f.name) + getattr(other, f.name))
        out.disk_space = max(self.disk_space, other.disk_space)
        return out

    def __str__(self) -> str:  # pragma: no cover - debugging aid
        d = self.as_dict()
        return "IOLedger(" + ", ".join(f"{k}={v:,}" for k, v in d.items()) + ")"


def _blocks(nbytes: int, block: int) -> int:
    """Number of block-granular I/O operations for an ``nbytes`` transfer."""
    if nbytes <= 0:
        return 0
    return -(-nbytes // block)
