"""Trace-time I/O ledger for the PEMS2 simulation.

The thesis measures algorithms by *I/O volume* (bytes moved between RAM and
external memory) and *number of I/O operations* (block transfers).  Both are
statically determined by the simulation parameters (v, P, k, mu, omega, B) and
the deterministic ID-ordered round schedule (thesis §6.5), so the ledger is a
pure-Python event counter updated at trace time.  Tests assert that the ledger
reproduces the thesis' closed forms (``repro.core.analysis``) exactly.

Byte categories mirror the thesis' cost terms:

* ``swap_in`` / ``swap_out``      — context swapping (the ``S`` coefficient)
* ``msg_direct``                  — messages delivered directly to a context on
                                    disk (PEMS2, §6.2)
* ``msg_indirect``                — messages staged through the indirect area
                                    (PEMS1, §2.2) or re-read for late delivery
* ``boundary``                    — boundary-block cache flushes (§6.2)
* ``network``                     — bytes crossing the real-processor network
                                    (the ``g`` coefficient)
* ``disk_space``                  — peak external-memory footprint (§6.3)

With a host/disk backing tier (``repro.core.backing``) the swaps are no longer
simulated: the executor's host-driven pipeline records the *measured* traffic
in a second group of counters (``h2d_bytes``/``d2h_bytes`` for PCIe-direction
transfers, ``disk_read_bytes``/``disk_write_bytes`` for the memmap file).
These are real bytes, not modeled blocks, and are deliberately excluded from
``io_total`` so the thesis' closed-form lemmas keep validating unchanged.
:class:`TierStats` carries the wall-clock side of the same pipeline (swap
time, stall time, the async driver's compute/I-O overlap fraction — §5.1).
"""

from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass
class IOLedger:
    """Byte counters for one simulated program run."""

    swap_in: int = 0
    swap_out: int = 0
    msg_direct: int = 0
    msg_indirect: int = 0
    boundary: int = 0
    network: int = 0
    network_rounds: int = 0   # bulk all-to-all launches of the α-chunked
                              # network phase (Alg 7.1.3; the ``l`` term of
                              # Lemma 7.1.7 counts P· this, point-to-point)
    disk_space: int = 0
    num_ios: int = 0          # block-granular I/O operations
    supersteps: int = 0       # internal superstep barriers (the ``L`` term)

    # Measured backing-tier traffic (host-driven pipeline; real bytes moved,
    # recorded at execution time — excluded from the modeled ``io_total``).
    h2d_bytes: int = 0        # host → device transfers (swap-in)
    d2h_bytes: int = 0        # device → host transfers (swap-out)
    disk_read_bytes: int = 0  # bytes read from the disk backing file
    disk_write_bytes: int = 0  # bytes written to the disk backing file

    # Syscall-level counters from the ``repro.io`` engine (``tier="file"``):
    # the bytes each pread/pwrite actually asked the kernel for.  Under the
    # ``odirect`` driver these are block-aligned and can exceed the logical
    # ``disk_*_bytes`` above (read-modify-write of boundary blocks); they are
    # the numbers to validate against ``os.stat`` block accounting.
    syscall_read_bytes: int = 0
    syscall_write_bytes: int = 0

    # ------------------------------------------------------------------ totals
    @property
    def swap_total(self) -> int:
        return self.swap_in + self.swap_out

    @property
    def message_total(self) -> int:
        return self.msg_direct + self.msg_indirect + self.boundary

    @property
    def io_total(self) -> int:
        """Total external-memory traffic (the thesis' "I/O volume")."""
        return self.swap_total + self.message_total

    # ------------------------------------------------------------------ events
    def add_swap_in(self, nbytes: int, block: int) -> None:
        self.swap_in += nbytes
        self.num_ios += _blocks(nbytes, block)

    def add_swap_out(self, nbytes: int, block: int) -> None:
        self.swap_out += nbytes
        self.num_ios += _blocks(nbytes, block)

    def add_msg_direct(self, nbytes: int, block: int) -> None:
        self.msg_direct += nbytes
        self.num_ios += _blocks(nbytes, block)

    def add_msg_indirect(self, nbytes: int, block: int) -> None:
        self.msg_indirect += nbytes
        self.num_ios += _blocks(nbytes, block)

    def add_boundary(self, nbytes: int, block: int) -> None:
        self.boundary += nbytes
        self.num_ios += _blocks(nbytes, block)

    def add_network(self, nbytes: int) -> None:
        self.network += nbytes

    def add_network_rounds(self, n: int) -> None:
        self.network_rounds += n

    def add_tier_in(self, nbytes: int, disk: bool) -> None:
        """Measured swap-in: host (or disk) → device."""
        self.h2d_bytes += nbytes
        if disk:
            self.disk_read_bytes += nbytes

    def add_tier_out(self, nbytes: int, disk: bool) -> None:
        """Measured swap-out: device → host (or disk)."""
        self.d2h_bytes += nbytes
        if disk:
            self.disk_write_bytes += nbytes

    def add_disk_read(self, nbytes: int) -> None:
        """Measured disk-resident data movement that never crosses to the
        device (host-side collectives over a memmap store)."""
        self.disk_read_bytes += nbytes

    def add_disk_write(self, nbytes: int) -> None:
        self.disk_write_bytes += nbytes

    @property
    def tier_total(self) -> int:
        """Total measured backing-tier traffic (both directions)."""
        return self.h2d_bytes + self.d2h_bytes

    def add_barrier(self, n: int = 1) -> None:
        self.supersteps += n

    def require_disk(self, nbytes: int) -> None:
        self.disk_space = max(self.disk_space, nbytes)

    # ---------------------------------------------------------------- reporting
    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self) | {
            "swap_total": self.swap_total,
            "message_total": self.message_total,
            "io_total": self.io_total,
            "tier_total": self.tier_total,
        }

    def snapshot(self, prefix: str = "ledger") -> Dict[str, int]:
        """Flat metric-name view of :meth:`as_dict` (``"ledger.swap_in"``,
        ...): the names under which these counters appear in the
        ``repro.obs`` metrics snapshot embedded in exported traces."""
        return {f"{prefix}.{k}": v for k, v in self.as_dict().items()}

    def merge(self, other: "IOLedger") -> "IOLedger":
        """Combine two ledgers: byte/op counters sum; ``disk_space`` (a
        per-process requirement, not a flow) takes the max.  Aggregates the
        per-shard ledgers of a ``P > 1`` run back to the ``P == 1`` totals
        — the sharding invariant the tier-1 tests pin."""
        out = IOLedger()
        for f in dataclasses.fields(IOLedger):
            setattr(out, f.name, getattr(self, f.name) + getattr(other, f.name))
        out.disk_space = max(self.disk_space, other.disk_space)
        return out

    def __str__(self) -> str:  # pragma: no cover - debugging aid
        d = self.as_dict()
        return "IOLedger(" + ", ".join(f"{k}={v:,}" for k, v in d.items()) + ")"


def _blocks(nbytes: int, block: int) -> int:
    """Number of block-granular I/O operations for an ``nbytes`` transfer."""
    if nbytes <= 0:
        return 0
    return -(-nbytes // block)


@dataclasses.dataclass
class TierStats:
    """Wall-clock instrumentation of the host-driven swap pipeline.

    ``swap_in_s`` is the time the (pre)fetcher spent reading the backing
    store and uploading to the device; ``stall_s`` is the main-thread time
    actually *blocked* waiting for a swap-in.  Under the synchronous drivers
    the two are equal; under the ``async`` driver the prefetch thread runs
    while the previous round computes, so ``stall_s < swap_in_s`` — the gap
    is the PEMS2 §5.1 compute/I-O overlap.
    """

    rounds: int = 0
    swap_in_s: float = 0.0
    swap_out_s: float = 0.0
    compute_s: float = 0.0    # round compute incl. the blocking D2H readback
    stall_s: float = 0.0
    peak_stage_bytes: int = 0  # largest host staging buffer a tiered
                               # collective allocated (≤ device_cap_bytes
                               # when the cap is set — see _alltoallv_host)

    # repro.io engine instrumentation (tier="file"): measured at the
    # submission/completion queues, not modeled.
    max_queue_depth: int = 0   # high-water mark of in-flight requests
    queue_stall_s: float = 0.0  # submit-side blocking on a full queue
    fsyncs: int = 0            # durability barriers issued by the engine
    rw_overlap_events: int = 0  # submissions that observed the *opposite*
                                # direction already in flight — >0 means
                                # reads and writes genuinely overlapped
    retries: int = 0           # transient-error re-attempts the engine issued
    backoff_s: float = 0.0     # scheduled retry backoff (deterministic sum)
    permanent_errors: int = 0  # requests that errored after retries exhausted
                               # (or a non-transient errno, first attempt)

    # Streamed-stage instrumentation (superstep(..., stream=True) on a disk
    # backing — the k-way merge stage of PSRS): the stage's bucket reads are
    # prefetched through the block API while the previous round's merge
    # computes, regardless of the configured driver.
    merge_prefetch_events: int = 0  # round swap-ins issued ahead of need,
                                    # overlapping the in-flight compute
    merge_stall_s: float = 0.0      # time the streamed stage still blocked
                                    # waiting on a prefetched round

    @property
    def overlap_fraction(self) -> float:
        """Fraction of swap-in time hidden behind compute (0 when nothing
        overlapped, → 1 when swap-ins were entirely free)."""
        if self.swap_in_s <= 0.0:
            return 0.0
        return min(1.0, max(0.0, 1.0 - self.stall_s / self.swap_in_s))

    def reset(self) -> None:
        for f in dataclasses.fields(TierStats):
            setattr(self, f.name, f.default)

    def merge(self, other: "TierStats") -> "TierStats":
        """Combine two pipelines' stats: counters and times sum; high-water
        marks (``peak_stage_bytes``, ``max_queue_depth``) take the max.
        Used to aggregate the per-shard stats of a ``P > 1`` tiered run."""
        out = TierStats()
        for f in dataclasses.fields(TierStats):
            setattr(out, f.name, getattr(self, f.name) + getattr(other, f.name))
        out.peak_stage_bytes = max(self.peak_stage_bytes,
                                   other.peak_stage_bytes)
        out.max_queue_depth = max(self.max_queue_depth, other.max_queue_depth)
        return out

    def as_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self) | {
            "overlap_fraction": self.overlap_fraction,
        }

    def snapshot(self, prefix: str = "tier") -> Dict[str, float]:
        """Flat metric-name view of :meth:`as_dict` (``"tier.stall_s"``,
        ...): the names under which these counters appear in the
        ``repro.obs`` metrics snapshot embedded in exported traces."""
        return {f"{prefix}.{k}": v for k, v in self.as_dict().items()}
