"""PEMS2 core: external-memory simulation of BSP algorithms in JAX.

Public API::

    from repro.core import (
        Pems, PemsConfig, ContextLayout, ContextStore, Ctx, Field,
        Allocator, IOLedger, analysis,
    )
"""

from . import analysis
from .backing import (
    FileBacking,
    HostBacking,
    MemmapBacking,
    TIERS,
    TieredStore,
    make_backing,
)
from .context import (
    Allocator,
    Ctx,
    ContextLayout,
    ContextStore,
    Field,
    WORD,
    init_store,
    layout,
)
from .executor import DRIVERS, Pems, PemsConfig
from .iostats import IOLedger, TierStats
from .recovery import SuperstepCursor, atomic_replace_file, atomic_write_json

__all__ = [
    "Allocator",
    "Ctx",
    "ContextLayout",
    "ContextStore",
    "DRIVERS",
    "Field",
    "FileBacking",
    "HostBacking",
    "IOLedger",
    "MemmapBacking",
    "Pems",
    "PemsConfig",
    "SuperstepCursor",
    "TIERS",
    "TieredStore",
    "TierStats",
    "WORD",
    "analysis",
    "atomic_replace_file",
    "atomic_write_json",
    "init_store",
    "layout",
    "make_backing",
]
