"""PEMS2 core: external-memory simulation of BSP algorithms in JAX.

Public API::

    from repro.core import (
        Pems, PemsConfig, ContextLayout, ContextStore, Ctx, Field,
        Allocator, IOLedger, analysis,
    )
"""

from . import analysis
from .context import (
    Allocator,
    Ctx,
    ContextLayout,
    ContextStore,
    Field,
    WORD,
    init_store,
    layout,
)
from .executor import DRIVERS, Pems, PemsConfig
from .iostats import IOLedger

__all__ = [
    "Allocator",
    "Ctx",
    "ContextLayout",
    "ContextStore",
    "DRIVERS",
    "Field",
    "IOLedger",
    "Pems",
    "PemsConfig",
    "WORD",
    "analysis",
    "init_store",
    "layout",
]
