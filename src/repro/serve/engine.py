"""Batched serving engine: prefill + greedy/temperature decode with jitted
steps and donated caches (buffer reuse across decode steps)."""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


class ServeEngine:
    def __init__(self, model, params, max_seq: int):
        self.model = model
        self.params = params
        self.max_seq = max_seq
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode, donate_argnums=(3,))

    def generate(
        self,
        prompts: jnp.ndarray,           # [B, S_prompt] int32
        steps: int,
        temperature: float = 0.0,
        rng: Optional[jax.Array] = None,
        extra_batch: Optional[Dict] = None,
    ) -> np.ndarray:
        """Greedy (or sampled) continuation of a batch of equal-length
        prompts; returns [B, steps] generated tokens."""
        b, s_prompt = prompts.shape
        cache = self.model.init_cache(b, self.max_seq)
        batch = {"tokens": prompts, **(extra_batch or {})}
        logits, cache = self._prefill(self.params, batch, cache)
        prefix = (self.model.cfg.n_frontend_tokens
                  if self.model.cfg.frontend == "patches" else 0)
        pos = s_prompt + prefix
        out = []
        tok = self._pick(logits[:, -1], temperature, rng, 0)
        for i in range(steps):
            out.append(tok)
            logits, cache = self._decode(self.params, tok, jnp.int32(pos),
                                         cache)
            pos += 1
            tok = self._pick(logits[:, -1], temperature, rng, i + 1)
        return np.concatenate([np.asarray(t) for t in out], axis=1)

    @staticmethod
    def _pick(logits, temperature, rng, i):
        if temperature <= 0.0 or rng is None:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        key = jax.random.fold_in(rng, i)
        return jax.random.categorical(
            key, logits / temperature, axis=-1).astype(jnp.int32)[:, None]
