"""Production training driver.

    python -m repro.launch.train --arch qwen2-1.5b --smoke --steps 100

Full-size configs expect a real TPU slice (the CPU container trains the
reduced ``--smoke`` variants); either way the driver exercises the complete
path: config → model → sharded data → train_step → checkpoints → resume.
Fault tolerance: checkpoints are atomic, restore picks the newest complete
one, and the data pipeline is step-addressable so a resumed run consumes
exactly the batches the crashed run would have.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import ARCH_NAMES, get_config
from repro.data import DataConfig, synthetic_batches
from repro.models import Model
from repro.optim import OptConfig
from repro.train import TrainConfig, init_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    model = Model(cfg)
    tcfg = TrainConfig(
        opt=OptConfig(lr=args.lr),
        microbatches=args.microbatches,
        warmup_steps=max(args.steps // 20, 1),
        total_steps=args.steps,
        grad_compress=args.grad_compress,
    )
    dcfg = DataConfig(
        seq_len=args.seq, global_batch=args.batch, vocab=cfg.vocab,
        frontend=cfg.frontend, n_frontend_tokens=cfg.n_frontend_tokens,
        d_model=cfg.d_model)

    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params / 1e6:.2f}M "
          f"steps={args.steps} batch={args.batch}x{args.seq}")

    state = init_train_state(params, tcfg)
    step_fn = jax.jit(make_train_step(model, tcfg))

    start = 0
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep=3)
        got = mgr.restore_latest(like=state)
        if got is not None:
            start, state = got
            print(f"resumed from step {start}")

    t0 = time.time()
    tokens = 0
    for i, batch in zip(range(start, args.steps),
                        synthetic_batches(dcfg, start_step=start)):
        state, metrics = step_fn(state, batch)
        tokens += args.batch * args.seq
        if (i + 1) % args.log_every == 0 or i + 1 == args.steps:
            dt = time.time() - t0
            print(f"step {i + 1:5d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['gnorm']):.3f} "
                  f"tok/s={tokens / dt:,.0f}")
        if mgr and ((i + 1) % args.ckpt_every == 0 or i + 1 == args.steps):
            mgr.save(i + 1, state, blocking=False)
    if mgr:
        mgr.wait()
    print("done")
    return state


if __name__ == "__main__":
    main()
