"""Batched serving driver.

    python -m repro.launch.serve --arch mamba2-130m --smoke --requests 8
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_config
from repro.models import Model
from repro.serve import ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if cfg.is_encoder_only:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode step")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    eng = ServeEngine(model, params,
                      max_seq=args.prompt_len + args.gen_len + 8)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.requests, args.prompt_len)),
        jnp.int32)

    t0 = time.time()
    out = eng.generate(prompts, steps=args.gen_len,
                       temperature=args.temperature,
                       rng=jax.random.PRNGKey(1))
    dt = time.time() - t0
    total = args.requests * args.gen_len
    print(f"arch={cfg.name} requests={args.requests} "
          f"generated={total} tokens in {dt:.2f}s "
          f"({total / dt:,.0f} tok/s)")
    print("sample:", out[0][:16].tolist())
    return out


if __name__ == "__main__":
    main()
