import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, with NO real allocation (ShapeDtypeStruct inputs).

For each cell this script records:
  * ``memory_analysis()``  — bytes per device (proves fit / quantifies misfit)
  * ``cost_analysis()``    — per-device FLOPs and bytes accessed (§Roofline)
  * collective bytes parsed from the post-optimisation HLO
  * the roofline terms and dominant bottleneck

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both --out artifacts/dryrun
"""

import argparse
import json
import time
import traceback
from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, ARCH_NAMES, get_config, skip_reason
from repro.data import DataConfig, make_batch_specs
from repro.distributed.sharding import (
    ShardingRules, batch_specs_sharded, cache_pspec, opt_pspecs, param_pspecs,
)
from repro.launch.mesh import data_axes_of, make_production_mesh
from repro.models import Model
from repro.optim import OptConfig, adamw_init
from repro.roofline import HW, collective_bytes, roofline_terms
from repro.train import TrainConfig, TrainState, make_train_step

# Per-arch execution choices (documented in EXPERIMENTS.md §Dry-run).
BIG_MOE = ("kimi-k2-1t-a32b", "arctic-480b")
FSDP_ARCHS = BIG_MOE + ("qwen3-14b",)
TRAIN_MICROBATCHES = 8


def _attach(specs_tree, pspecs_tree, mesh):
    def one(s, spec):
        return jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, spec))

    return jax.tree.map(one, specs_tree, pspecs_tree,
                        is_leaf=lambda x: hasattr(x, "shape"))


def build_cell(arch: str, shape_name: str, mesh, *, n_layers=None,
               global_batch=None, microbatches=None, cfg_overrides=None):
    """Returns (lower_fn, meta) for one (arch × shape) cell.

    ``n_layers``/``global_batch``/``microbatches`` overrides exist for the
    calibrated cost model (repro.roofline.calibrate): XLA's cost_analysis
    counts loop bodies once, so per-layer / per-microbatch costs are probed
    at two layer counts and two batch sizes and extrapolated linearly.
    """
    import dataclasses as _dc
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if n_layers is not None:
        cfg = _dc.replace(cfg, n_layers=n_layers)
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    if global_batch is not None:
        shape = _dc.replace(shape, global_batch=global_batch)
    # NB (§Perf iteration #6, refuted): disabling FSDP at inference sounded
    # free (no optimizer state to shard) but parameter *residency* still
    # needs the data axis for the giants — kimi prefill went 80→443 GB/dev.
    rules = ShardingRules(
        mesh=mesh, data_axes=data_axes_of(mesh), fsdp=arch in FSDP_ARCHS)
    if cfg.is_moe:
        # Hierarchical MoE dispatch: one token group per DP shard keeps every
        # dispatch intermediate sharded (DESIGN.md §3.1).
        cfg = _dc.replace(cfg, moe_groups=rules.data_size)
    model = Model(cfg)

    if cfg.is_moe:
        # §Perf iteration #9: the MoE group reshape otherwise steers the
        # residual stream to replicated-batch layouts (arctic: 116→54 GB/dev).
        # Dense archs are already well-placed — pinning them costs ~1 GB.
        def _act_pin(x):
            if x.ndim == 3 and x.shape[0] % rules.data_size == 0:
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, P(rules.data_axes, None, None)))
            return x

        model.act_constraint = _act_pin

    rng = jax.random.PRNGKey(0)
    params_s = jax.eval_shape(model.init, rng)
    pspecs = param_pspecs(rules, params_s)
    params_in = _attach(params_s, pspecs, mesh)

    dcfg = DataConfig(
        seq_len=shape.seq_len, global_batch=shape.global_batch,
        vocab=cfg.vocab, frontend=cfg.frontend,
        n_frontend_tokens=cfg.n_frontend_tokens, d_model=cfg.d_model)

    n_params = sum(_size(l.shape) for l in jax.tree.leaves(params_s))
    expert_params = sum(
        _size(l.shape)
        for path, l in jax.tree_util.tree_flatten_with_path(params_s)[0]
        if "moe" in jax.tree_util.keystr(path)
        and any(s in jax.tree_util.keystr(path) for s in ("w_in", "w_out")))
    n_active = (n_params - expert_params
                + expert_params * cfg.top_k / max(cfg.n_experts, 1))

    meta = {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "mesh": dict(zip(mesh.axis_names, mesh.devices.shape)),
        "n_params": int(n_params), "n_params_active": int(n_active),
        "fsdp": rules.fsdp,
    }

    if shape.kind == "train":
        ocfg = OptConfig(quantize_moments=arch in BIG_MOE,
                         scan_stacked=arch in BIG_MOE + FSDP_ARCHS)
        tcfg = TrainConfig(
            opt=ocfg,
            microbatches=(TRAIN_MICROBATCHES if microbatches is None
                          else microbatches),
            accum_dtype="bfloat16" if arch in BIG_MOE else "float32")
        opt_s = jax.eval_shape(lambda p: adamw_init(p, ocfg), params_s)
        ospecs = opt_pspecs(rules, opt_s, params_s)
        state_in = TrainState(
            params=params_in,
            opt=_attach(opt_s, ospecs, mesh),
            ef=None)
        batch_in = batch_specs_sharded(rules, make_batch_specs(dcfg))

        def mb_shard(x):
            spec = P(None, rules.data_axes,
                     *(None,) * (x.ndim - 2))
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, spec))

        step = make_train_step(model, tcfg, microbatch_sharding=mb_shard)
        meta["optimizer"] = ("adamw-int8" if ocfg.quantize_moments
                             else "adamw-f32")
        meta["microbatches"] = tcfg.microbatches

        def lower():
            return jax.jit(step, donate_argnums=(0,)).lower(
                state_in, batch_in)

        # tokens processed per step (for MFU-style normalisation)
        meta["tokens"] = shape.global_batch * shape.seq_len
        return lower, meta

    # serving shapes
    cache_s = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))
    cspecs = cache_pspec(rules, cache_s)
    cache_in = _attach(cache_s, cspecs, mesh)

    if shape.kind == "prefill":
        batch_in = batch_specs_sharded(rules, make_batch_specs(dcfg))

        def prefill(params, batch, cache):
            return model.prefill(params, batch, cache)

        def lower():
            return jax.jit(prefill, donate_argnums=(2,)).lower(
                params_in, batch_in, cache_in)

        meta["tokens"] = shape.global_batch * shape.seq_len
        return lower, meta

    # decode: one new token against a seq_len-deep cache
    b = shape.global_batch
    tok_spec = P(rules.data_axes if b % rules.data_size == 0 else None, None)
    tokens_in = jax.ShapeDtypeStruct(
        (b, 1), jnp.int32, sharding=NamedSharding(mesh, tok_spec))
    pos_in = jax.ShapeDtypeStruct((), jnp.int32)

    def decode(params, tokens, pos, cache):
        return model.decode(params, tokens, pos, cache)

    def lower():
        return jax.jit(decode, donate_argnums=(3,)).lower(
            params_in, tokens_in, pos_in, cache_in)

    meta["tokens"] = shape.global_batch
    return lower, meta


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             save_hlo: bool = False) -> Dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    lower_fn, meta = build_cell(arch, shape_name, mesh)
    lowered = lower_fn()
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        # Older jax returns a one-element-per-device list of dicts; newer
        # jax returns the dict directly.
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    terms = roofline_terms(flops, bytes_acc, coll["weighted_bytes"])

    n_chips = 1
    for v in meta["mesh"].values():
        n_chips *= v
    model_fl = (6.0 if meta["kind"] == "train" else 2.0) * \
        meta["n_params_active"] * meta["tokens"]
    device_bytes = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                    + mem.temp_size_in_bytes - mem.alias_size_in_bytes)

    result = {
        **meta,
        "multi_pod": multi_pod,
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "per_device_bytes": device_bytes,
            "fits_hbm": bool(device_bytes <= HW["hbm_bytes"]),
        },
        "cost": {"flops_per_device": flops,
                 "bytes_per_device": bytes_acc},
        "collectives": coll,
        "roofline": terms,
        "model_flops_total": model_fl,
        "model_flops_per_device": model_fl / n_chips,
        "useful_flop_ratio": (model_fl / n_chips) / flops if flops else 0.0,
    }
    if save_hlo:
        result["hlo_len"] = len(hlo)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_NAMES)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    cells = []
    archs = ARCH_NAMES if (args.all or args.arch is None) else [args.arch]
    for arch in archs:
        cfg = get_config(arch)
        shapes = ([args.shape] if args.shape else list(SHAPES))
        for sh in shapes:
            reason = skip_reason(cfg, sh)
            cells.append((arch, sh, reason))

    if args.list:
        for arch, sh, reason in cells:
            print(f"{arch:24s} {sh:12s} {'SKIP: ' + reason if reason else 'RUN'}")
        return

    os.makedirs(args.out, exist_ok=True)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}
    failures = 0
    for arch, sh, reason in cells:
        if reason:
            out = {"arch": arch, "shape": sh, "skipped": reason}
            _write(args.out, arch, sh, "any", out)
            print(f"SKIP {arch} {sh}: {reason}")
            continue
        for mp in meshes[args.mesh]:
            tag = "multi" if mp else "single"
            try:
                res = run_cell(arch, sh, mp)
                _write(args.out, arch, sh, tag, res)
                r = res["roofline"]
                print(f"OK   {arch} {sh} [{tag}] compile={res['compile_s']}s "
                      f"bytes/dev={res['memory']['per_device_bytes']/1e9:.2f}GB "
                      f"dominant={r['dominant']} "
                      f"frac={r['roofline_fraction']:.3f}", flush=True)
            except Exception as e:
                failures += 1
                _write(args.out, arch, sh, tag,
                       {"arch": arch, "shape": sh, "mesh": tag,
                        "error": str(e),
                        "traceback": traceback.format_exc()})
                print(f"FAIL {arch} {sh} [{tag}]: {e}", flush=True)
    if failures:
        raise SystemExit(f"{failures} cells failed")


def _write(out, arch, sh, tag, payload):
    fn = os.path.join(out, f"{arch}__{sh}__{tag}.json")
    with open(fn, "w") as f:
        json.dump(payload, f, indent=1)


def _size(shape) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out


if __name__ == "__main__":
    main()
