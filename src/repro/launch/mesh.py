"""Production mesh definitions.

A function (never a module-level constant) so importing this module never
touches JAX device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, and smoke tests must keep seeing 1 device.
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod (TPU v5e); 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def data_axes_of(mesh) -> tuple:
    """Axes usable for batch/data parallelism on this mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
