"""Production mesh definitions.

A function (never a module-level constant) so importing this module never
touches JAX device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, and smoke tests must keep seeing 1 device.
"""

from __future__ import annotations

import jax


def make_mesh_auto(shape, axes):
    """``jax.make_mesh`` with every axis in Auto mode.  ``AxisType`` only
    exists on newer jax; older releases have no explicit-axis meshes, so
    Auto is already the (only) behaviour and the kwarg is simply omitted."""
    try:
        from jax.sharding import AxisType
    except ImportError:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod (TPU v5e); 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_auto(shape, axes)


def data_axes_of(mesh) -> tuple:
    """Axes usable for batch/data parallelism on this mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
