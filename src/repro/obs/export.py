"""Chrome/Perfetto ``trace_event`` JSON export + per-process trace merge.

Produces the JSON *object* format (``{"traceEvents": [...], ...}``), which
both ``chrome://tracing`` and https://ui.perfetto.dev load directly and
which permits extra top-level keys — the flat metrics snapshot rides along
under ``"metrics"`` so one file carries spans *and* the ``TierStats``/
``IOLedger`` counters they must agree with.

Lane layout: each tracer becomes one Perfetto *process* (``pid``) — the
executor's main tracer is pid 0, shard ``p``'s engine/round tracer pid
``p+1`` — and each distinct ``tid`` string inside a tracer becomes one
named *thread* lane.  Timestamps are exported in microseconds as the
format requires.

Balance sanitation: ``B``/``E`` events are matched per lane on export —
an orphan ``E`` (its ``B`` fell off the ring) is dropped, and a ``B``
still open at the end of the buffer is closed at the last seen timestamp —
so every exported trace nests cleanly no matter where the ring wrapped or
where a crash cut the run.
"""

from __future__ import annotations

import json
from typing import Dict, Iterable, List, Optional

__all__ = ["trace_events", "write_trace", "merge_trace_files", "load_trace"]

_US = 1e6


def _balanced(events: list) -> list:
    """Drop orphan E events and close dangling B events per (tid, lane)."""
    out = []
    stacks: Dict[str, List[int]] = {}       # tid -> indices of open B's
    last_ts: Dict[str, float] = {}
    for ev in events:
        ph, name, tid, ts = ev[0], ev[1], ev[2], ev[3]
        last_ts[tid] = max(last_ts.get(tid, ts), ts)
        if ph == "B":
            stacks.setdefault(tid, []).append(len(out))
        elif ph == "E":
            if not stacks.get(tid):
                continue                    # orphan end: B fell off the ring
            stacks[tid].pop()
        out.append(ev)
    for tid, open_bs in stacks.items():
        for i in reversed(open_bs):         # close innermost first
            b = out[i]
            out.append(("E", b[1], tid, last_ts[tid], None, None, None))
    return out


def trace_events(tracer, pid: int,
                 process_name: Optional[str] = None) -> List[dict]:
    """Convert one tracer's ring into Chrome trace_event dicts under
    ``pid``, with process/thread metadata and balanced B/E nesting."""
    name = process_name or getattr(tracer, "name", f"pid{pid}")
    out: List[dict] = [{
        "ph": "M", "pid": pid, "tid": 0, "name": "process_name",
        "args": {"name": name},
    }]
    tids: Dict[str, int] = {}
    for ev in _balanced(tracer.events()):
        ph, ev_name, tid_s, ts, dur, cat, args = ev
        tid = tids.get(tid_s)
        if tid is None:
            tid = tids[tid_s] = len(tids) + 1
            out.append({"ph": "M", "pid": pid, "tid": tid,
                        "name": "thread_name", "args": {"name": tid_s}})
        rec = {"ph": ph, "pid": pid, "tid": tid, "name": ev_name,
               "ts": ts * _US}
        if ph == "X":
            rec["dur"] = dur * _US
        if ph == "i":
            rec["s"] = "t"                  # thread-scoped instant
        if cat is not None:
            rec["cat"] = cat
        if args is not None:
            rec["args"] = args
        out.append(rec)
    return out


def write_trace(path: str, events: Iterable[dict],
                metrics: Optional[dict] = None) -> str:
    """Write one Perfetto-loadable JSON object trace file."""
    doc = {"traceEvents": list(events), "displayTimeUnit": "ms"}
    if metrics is not None:
        doc["metrics"] = metrics
    with open(path, "w") as f:
        json.dump(doc, f)
    return path


def load_trace(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def merge_trace_files(path: str, part_paths: Iterable[str],
                      extra_events: Iterable[dict] = (),
                      metrics: Optional[dict] = None) -> str:
    """Merge per-process trace files (plus ``extra_events``, e.g. the main
    tracer's already-converted events) into one trace at ``path``.

    Events keep their pids (each part file was exported under its own), so
    the merged view shows one Perfetto process lane per source process;
    part-file ``metrics`` dicts are folded under the part's process name.
    """
    events: List[dict] = list(extra_events)
    merged_metrics: dict = dict(metrics or {})
    for pp in part_paths:
        doc = load_trace(pp)
        events.extend(doc.get("traceEvents", ()))
        for k, v in doc.get("metrics", {}).items():
            merged_metrics.setdefault(k, v)
    events.sort(key=lambda e: (e["ph"] != "M", e.get("ts", 0.0)))
    return write_trace(path, events,
                       metrics=merged_metrics if merged_metrics else None)
