"""Structured span tracer: the time-resolved twin of ``TierStats``.

``TierStats``/``IOLedger`` answer *how much* (seconds stalled, bytes
moved); they cannot answer *when* — which superstep stalled, which shard's
queue backed up, why ``merge_stall_s`` was nonzero.  :class:`Tracer` records
that: begin/end spans, complete spans, instant events, and counter samples
into a bounded ring buffer, exported as Chrome/Perfetto ``trace_event``
JSON (:mod:`repro.obs.export`) and summarized by ``python -m repro.obs
report`` (:mod:`repro.obs.report`).

Design constraints (and how they are met):

* **Low overhead.**  One event is one tuple appended to a
  ``collections.deque(maxlen=capacity)`` — no dict building, no I/O, no
  locking on the hot path (CPython's deque append is atomic, which is all
  the single-producer-per-lane usage here needs).  When tracing is off the
  plumbing holds the :data:`NOOP` singleton, so instrumented code pays one
  attribute check (``tracer.enabled``) or one no-op method call.
* **Bounded memory.**  The ring drops the *oldest* events past
  ``capacity`` (``dropped`` counts them) — a week-long run cannot OOM on
  its own telemetry.
* **Monotonic clock.**  Timestamps are ``time.perf_counter()`` relative to
  a shared ``epoch``, immune to wall-clock steps.  Tracers that should
  share a timeline (the executor's per-shard tracers) are constructed with
  the same ``epoch`` so their events merge onto comparable timestamps.
* **Exact agreement with the stats.**  :meth:`Tracer.complete` takes the
  *caller's* ``t0``/``t1`` perf_counter readings — the executor passes the
  very same values it adds into ``TierStats``, so a report derived from
  spans can never disagree with the counters.

Spans must stay **outside jitted code**: a span inside a traced function
fires once at trace time (the ``trace-purity`` invariant).  The executor
therefore skips whole-program jit when tracing is enabled.
"""

from __future__ import annotations

import collections
import time
from typing import Optional

__all__ = ["Tracer", "NoopTracer", "NOOP"]

# Event tuples: (ph, name, tid, ts_s, dur_s, cat, args)
#   ph  — Chrome trace_event phase: "X" complete, "B"/"E" begin/end,
#         "i" instant, "C" counter
#   ts_s/dur_s — seconds since the tracer's epoch / span length
#   args — small dict of attributes (None when empty)


class _Span:
    """Context manager for one complete ("X") span.  ``duration_s`` is
    available after exit — benchmarks time *through* the span so their
    numbers and the trace can never disagree."""

    __slots__ = ("_tracer", "name", "tid", "cat", "args", "t0", "t1")

    def __init__(self, tracer: "Tracer", name: str, tid: str,
                 cat: Optional[str], args: Optional[dict]):
        self._tracer = tracer
        self.name = name
        self.tid = tid
        self.cat = cat
        self.args = args
        self.t0 = 0.0
        self.t1 = 0.0

    def __enter__(self) -> "_Span":
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.t1 = time.perf_counter()
        self._tracer.complete(self.name, self.t0, self.t1, tid=self.tid,
                              cat=self.cat, **(self.args or {}))
        return False

    @property
    def duration_s(self) -> float:
        return self.t1 - self.t0


class Tracer:
    """Bounded ring-buffer span/event recorder (one per process lane)."""

    enabled = True

    def __init__(self, capacity: int = 1 << 16,
                 epoch: Optional[float] = None, name: str = "main"):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.name = name
        self.capacity = capacity
        self.epoch = time.perf_counter() if epoch is None else epoch
        self._events = collections.deque(maxlen=capacity)
        self.dropped = 0        # advisory: events evicted by the ring

    # ---------------------------------------------------------------- clock
    def now(self) -> float:
        """Raw ``time.perf_counter()`` — pair with :meth:`complete`."""
        return time.perf_counter()

    # --------------------------------------------------------------- events
    def _push(self, ev: tuple) -> None:
        if len(self._events) == self.capacity:
            self.dropped += 1   # advisory count; benign under races
        self._events.append(ev)

    def span(self, name: str, tid: str = "main", cat: Optional[str] = None,
             **args) -> _Span:
        """``with tracer.span("stage:merge", tid="stages"): ...`` — records
        one complete span from enter to exit."""
        return _Span(self, name, tid, cat, args or None)

    def complete(self, name: str, t0: float, t1: float, tid: str = "main",
                 cat: Optional[str] = None, **args) -> None:
        """Record an already-timed region: ``t0``/``t1`` are the caller's
        ``time.perf_counter()`` readings (the same values it billed into
        its stats counters)."""
        self._push(("X", name, tid, t0 - self.epoch, t1 - t0, cat,
                    args or None))

    def begin(self, name: str, tid: str = "main",
              cat: Optional[str] = None, **args) -> None:
        """Open a nested span; close it with :meth:`end` on the same lane.
        For spans confined to one scope prefer :meth:`span` — the
        ``trace-balance`` lint rule flags a ``begin`` without a matching
        ``end`` in the same scope."""
        self._push(("B", name, tid, time.perf_counter() - self.epoch,
                    None, cat, args or None))

    def end(self, name: str, tid: str = "main") -> None:
        self._push(("E", name, tid, time.perf_counter() - self.epoch,
                    None, None, None))

    def instant(self, name: str, tid: str = "events",
                cat: Optional[str] = None, **args) -> None:
        """Zero-duration marker (fault injections, sanitizer findings,
        drain timeouts)."""
        self._push(("i", name, tid, time.perf_counter() - self.epoch,
                    None, cat, args or None))

    def counter(self, name: str, value, tid: str = "counters") -> None:
        """One sample of a counter track (e.g. engine queue depth)."""
        self._push(("C", name, tid, time.perf_counter() - self.epoch,
                    None, None, {"value": value}))

    # ------------------------------------------------------------ inspection
    def events(self) -> list:
        """Snapshot of the ring's event tuples, oldest first."""
        return list(self._events)

    def __len__(self) -> int:
        return len(self._events)

    def clear(self) -> None:
        self._events.clear()
        self.dropped = 0


class _NoopSpan:
    """Shared do-nothing span: zero allocation per disabled ``span()``."""

    __slots__ = ()
    t0 = 0.0
    t1 = 0.0
    duration_s = 0.0

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


_NOOP_SPAN = _NoopSpan()


class NoopTracer:
    """Disabled tracer: every method is a no-op, ``enabled`` is False so
    hot paths can skip even argument construction.  Use the shared
    :data:`NOOP` singleton."""

    enabled = False
    name = "noop"
    epoch = 0.0
    capacity = 0
    dropped = 0

    def now(self) -> float:
        return time.perf_counter()

    def span(self, name: str, tid: str = "main", cat=None, **args):
        return _NOOP_SPAN

    def complete(self, name, t0, t1, tid="main", cat=None, **args) -> None:
        pass

    def begin(self, name, tid="main", cat=None, **args) -> None:
        pass

    def end(self, name, tid="main") -> None:
        pass

    def instant(self, name, tid="events", cat=None, **args) -> None:
        pass

    def counter(self, name, value, tid="counters") -> None:
        pass

    def events(self) -> list:
        return []

    def __len__(self) -> int:
        return 0

    def clear(self) -> None:
        pass


NOOP = NoopTracer()
