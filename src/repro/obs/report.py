"""Summarize an exported trace: where did the time actually go?

``python -m repro.obs report <trace.json>`` prints, from the spans alone:

* a per-phase (plan-stage) breakdown of compute vs I/O vs stall time,
* the measured compute/I-O overlap fraction, cross-checked against the
  ``TierStats.overlap_fraction`` embedded in the trace's metrics snapshot
  (the two derive from the same ``perf_counter`` readings, so they must
  agree — a mismatch means instrumentation drift),
* the top-N slowest engine requests (driver, bytes, retries).

Span taxonomy consumed here (see docs/ARCHITECTURE.md "Observability"):

* ``cat="stage"``      — one span per plan stage (main tracer, pid 0)
* ``cat="compute"``    — round compute (per-shard tracers)
* ``cat="io"``         — executor-level swap_in/swap_out wall time
* ``cat="stall"``      — main-thread time blocked waiting on a swap-in
* ``cat="request"``    — one span per engine request (worker lanes)

Everything is stdlib; the module is import-independent of jax/numpy so the
CLI runs anywhere the trace file can be copied.
"""

from __future__ import annotations

import json
from typing import List, Optional

__all__ = ["summarize", "render", "report"]


def _xspans(trace: dict) -> List[dict]:
    return [e for e in trace.get("traceEvents", ())
            if e.get("ph") == "X"]


def summarize(trace: dict, top: int = 10) -> dict:
    """Reduce a loaded trace document to the report's numbers.

    Returns a dict with ``stages`` (per-stage rows), ``totals`` (summed
    compute/io/stall seconds and the span-derived ``overlap_fraction``),
    ``metrics_overlap`` (the ``TierStats`` value embedded at export, or
    None), and ``slowest`` (top-N request spans by duration).
    """
    spans = _xspans(trace)
    stages = sorted((e for e in spans if e.get("cat") == "stage"),
                    key=lambda e: e["ts"])
    buckets = {"compute": "compute_s", "io": "io_s", "stall": "stall_s"}
    rows = [{
        "name": s["name"], "ts": s["ts"], "dur": s.get("dur", 0.0),
        "wall_s": s.get("dur", 0.0) / 1e6,
        "compute_s": 0.0, "io_s": 0.0, "stall_s": 0.0,
    } for s in stages]
    totals = {"compute_s": 0.0, "io_s": 0.0, "stall_s": 0.0,
              "swap_in_s": 0.0, "unattributed_s": 0.0}

    for e in spans:
        key = buckets.get(e.get("cat"))
        if key is None:
            continue
        dur_s = e.get("dur", 0.0) / 1e6
        totals[key] += dur_s
        if e.get("name") == "swap_in":
            totals["swap_in_s"] += dur_s
        mid = e["ts"] + e.get("dur", 0.0) / 2.0
        for row in rows:
            if row["ts"] <= mid < row["ts"] + row["dur"]:
                row[key] += dur_s
                break
        else:
            totals["unattributed_s"] += dur_s

    # Same formula as TierStats.overlap_fraction, computed from the spans.
    if totals["swap_in_s"] > 0.0:
        overlap = min(1.0, max(
            0.0, 1.0 - totals["stall_s"] / totals["swap_in_s"]))
    else:
        overlap = 0.0
    totals["overlap_fraction"] = overlap

    reqs = sorted((e for e in spans if e.get("cat") == "request"),
                  key=lambda e: -e.get("dur", 0.0))[:top]
    slowest = [{
        "op": e["name"], "dur_s": e.get("dur", 0.0) / 1e6,
        **{k: v for k, v in e.get("args", {}).items()},
    } for e in reqs]

    metrics = trace.get("metrics", {})
    return {
        "stages": rows,
        "totals": totals,
        "overlap_fraction": overlap,
        "metrics_overlap": metrics.get("tier.overlap_fraction"),
        "metrics": metrics,
        "slowest": slowest,
        "events": len(trace.get("traceEvents", ())),
    }


def render(summary: dict) -> str:
    """The report as human-readable text."""
    out = [f"trace: {summary['events']} events"]
    if summary["stages"]:
        out.append("")
        out.append(f"{'phase':<20} {'wall_s':>9} {'compute_s':>10} "
                   f"{'io_s':>9} {'stall_s':>9}")
        for r in summary["stages"]:
            out.append(f"{r['name']:<20} {r['wall_s']:>9.4f} "
                       f"{r['compute_s']:>10.4f} {r['io_s']:>9.4f} "
                       f"{r['stall_s']:>9.4f}")
    t = summary["totals"]
    out.append("")
    out.append(f"{'total':<20} {'':>9} {t['compute_s']:>10.4f} "
               f"{t['io_s']:>9.4f} {t['stall_s']:>9.4f}")
    if t["unattributed_s"] > 0.0:
        out.append(f"  ({t['unattributed_s']:.4f}s outside any stage span)")
    out.append("")
    out.append(f"overlap fraction (spans):     "
               f"{summary['overlap_fraction']:.3f}  "
               f"(1 - stall {t['stall_s']:.4f}s / "
               f"swap_in {t['swap_in_s']:.4f}s)")
    mo = summary["metrics_overlap"]
    if mo is not None:
        delta = abs(summary["overlap_fraction"] - mo)
        out.append(f"overlap fraction (TierStats): {mo:.3f}  "
                   f"(delta {delta:.3f})")
    if summary["slowest"]:
        out.append("")
        out.append("slowest requests:")
        for r in summary["slowest"]:
            extra = " ".join(f"{k}={v}" for k, v in r.items()
                             if k not in ("op", "dur_s"))
            out.append(f"  {r['op']:<6} {r['dur_s'] * 1e3:>9.3f} ms  "
                       f"{extra}")
    return "\n".join(out)


def report(path: str, top: int = 10) -> str:
    """Load ``path`` and return the rendered report."""
    with open(path) as f:
        trace = json.load(f)
    return render(summarize(trace, top=top))
