"""repro.obs — structured span tracing + metrics export.

The time-resolved observability layer over the superstep/I-O/recovery
stack: a bounded ring-buffer :class:`Tracer` (spans, instants, counters;
:data:`NOOP` singleton when disabled), Chrome/Perfetto ``trace_event``
JSON export with per-process lane merge (:mod:`repro.obs.export`), and a
stdlib report CLI (``python -m repro.obs report <trace>``).

Enable via ``PemsConfig(trace=True, trace_path="/tmp/run.json")`` and
export with ``pems.export_trace()``; see docs/ARCHITECTURE.md
"Observability" for the span taxonomy and lane layout.
"""

from .export import load_trace, merge_trace_files, trace_events, write_trace
from .report import render, report, summarize
from .tracer import NOOP, NoopTracer, Tracer

__all__ = [
    "Tracer", "NoopTracer", "NOOP",
    "trace_events", "write_trace", "merge_trace_files", "load_trace",
    "summarize", "render", "report",
]
