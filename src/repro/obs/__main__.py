"""CLI: ``python -m repro.obs report <trace.json> [--top N]``.

Stdlib-only — runs anywhere the exported trace file can be copied, no jax
or numpy required.
"""

from __future__ import annotations

import argparse
import sys

from .report import report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Summarize a repro.obs trace (compute/I-O/stall per "
                    "phase, overlap cross-check, slowest requests).")
    sub = ap.add_subparsers(dest="cmd", required=True)
    rp = sub.add_parser("report", help="summarize an exported trace")
    rp.add_argument("trace", help="trace JSON written by Pems.export_trace")
    rp.add_argument("--top", type=int, default=10,
                    help="slowest requests to list (default 10)")
    args = ap.parse_args(argv)
    print(report(args.trace, top=args.top))
    return 0


if __name__ == "__main__":
    sys.exit(main())
