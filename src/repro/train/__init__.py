from .train_step import TrainConfig, TrainState, make_train_step, init_train_state

__all__ = ["TrainConfig", "TrainState", "init_train_state", "make_train_step"]
