"""Training step: microbatch superstep accumulation, optional int8
error-feedback gradient compression, AdamW, cosine schedule.

Microbatches are the PEMS pattern at the training level: the global batch's
activations never coexist — ``lax.scan`` over microbatch rounds keeps only
one round resident (remat inside, f32 grad accumulator as the carried
"context").
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim import OptConfig, adamw_init, adamw_update, cosine_schedule


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    microbatches: int = 1
    warmup_steps: int = 100
    total_steps: int = 10_000
    grad_compress: bool = False   # int8 + error feedback on the DP reduce
    accum_dtype: str = "float32"  # bf16 halves the accumulator for T-param MoE


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Dict
    ef: Optional[Any]            # error-feedback residuals (compression)


def init_train_state(params, tcfg: TrainConfig) -> TrainState:
    ef = (jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
          if tcfg.grad_compress else None)
    return TrainState(params=params, opt=adamw_init(params, tcfg.opt), ef=ef)


def _compress_ef(grads, ef, block: int = 2048):
    """int8 blockwise quantization with error feedback: the residual of each
    round is added back next round, so compression error does not accumulate
    (what the DP all-reduce would carry on the wire)."""
    def one(g, e):
        x = g.astype(jnp.float32) + e
        n = x.size
        nb = -(-n // block)
        flat = jnp.pad(x.reshape(-1), (0, nb * block - n)).reshape(nb, block)
        scale = jnp.max(jnp.abs(flat), axis=1)
        safe = jnp.where(scale == 0.0, 1.0, scale)
        q = jnp.round(jnp.clip(flat / safe[:, None] * 127.0, -127, 127))
        deq = (q * safe[:, None] / 127.0).reshape(-1)[:n].reshape(g.shape)
        return deq.astype(g.dtype), (x - deq)

    out = jax.tree.map(one, grads, ef)
    return (jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple)),
            jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple)))


def make_train_step(model, tcfg: TrainConfig, microbatch_sharding=None):
    """Returns jit-able ``train_step(state, batch) -> (state, metrics)``.

    ``microbatch_sharding(x)``, when given, re-constrains each reshaped
    ``[n_mb, mb, ...]`` input so GSPMD keeps the *batch* dim sharded on the
    data axes (scanning over a sharded microbatch dim would force gathers).
    """
    nmb = tcfg.microbatches

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch)
        return loss, metrics, grads

    def train_step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        params = state.params
        if nmb == 1:
            loss, metrics, grads = grads_of(params, batch)
        else:
            mbs = jax.tree.map(
                lambda x: x.reshape((nmb, x.shape[0] // nmb) + x.shape[1:]),
                batch)
            if microbatch_sharding is not None:
                mbs = jax.tree.map(microbatch_sharding, mbs)

            acc_dt = jnp.dtype(tcfg.accum_dtype)

            def round_fn(acc, mb):
                loss_a, g_acc = acc
                loss, _, g = grads_of(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(acc_dt), g_acc, g)
                return (loss_a + loss, g_acc), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)
            (loss_sum, grads), _ = jax.lax.scan(
                round_fn, (jnp.zeros(()), zero), mbs)
            loss = loss_sum / nmb
            grads = jax.tree.map(lambda g: g / nmb, grads)

        ef = state.ef
        if tcfg.grad_compress:
            grads, ef = _compress_ef(grads, ef)

        lr_scale = cosine_schedule(
            state.opt["step"], warmup=tcfg.warmup_steps,
            total=tcfg.total_steps)
        new_params, new_opt, opt_metrics = adamw_update(
            params, grads, state.opt, tcfg.opt, lr_scale)
        out_metrics = {"loss": loss, **opt_metrics}
        return TrainState(new_params, new_opt, ef), out_metrics

    return train_step
