"""Model / run configuration.

One frozen dataclass covers all ten assigned architecture families; each
``configs/<arch>.py`` instantiates it with the exact published numbers.
``smoke()`` derives the reduced same-family variant used by CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0              # 0 → d_model // n_heads

    # attention
    qkv_bias: bool = False
    qk_norm: bool = False
    causal: bool = True          # False → encoder-only (no decode step)
    rope_theta: float = 10_000.0
    local_window: int = 0        # >0 → sliding-window attention
    act: str = "swiglu"          # swiglu | geglu
    embed_scale: bool = False    # gemma: scale embeddings by sqrt(d)
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_dense_residual: bool = False  # arctic: dense FFN parallel to MoE
    first_dense_layers: int = 0       # kimi/deepseek: leading dense layers
    capacity_factor: float = 1.25
    moe_dense_d_ff: int = 0           # d_ff of dense layers/residual (0 → d_ff)
    moe_groups: int = 1               # hierarchical dispatch groups (= DP shards)

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4

    # hybrid (recurrentgemma)
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    lru_width: int = 0

    # modality frontend (stubbed: input_specs provides embeddings)
    frontend: str = "none"       # none | patches | frames
    n_frontend_tokens: int = 0

    # numerics / execution
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    attn_chunk: int = 1024       # kv/q chunking for the streaming attention
    remat: str = "layer"         # none | layer
    unroll_layers: bool = False  # python-loop layers (cost-model probes)
    source: str = ""

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests: small widths,
        few layers/experts, tiny vocab — same code paths."""
        pattern = self.block_pattern[: 3] if self.block_pattern else ()
        n_layers = (len(pattern) + 1) if pattern else 2
        if self.first_dense_layers:
            n_layers = max(n_layers, 2)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=n_layers,
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            d_head=16,
            d_ff=128,
            moe_dense_d_ff=128 if self.moe_dense_d_ff else 0,
            vocab=256,
            n_experts=min(self.n_experts, 8),
            top_k=min(self.top_k, 2),
            # No token dropping in smoke tests: capacity effects are exercised
            # separately (test_models.py::test_moe_capacity_drops).
            capacity_factor=8.0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_headdim=16 if self.ssm_state else self.ssm_headdim,
            lru_width=64 if self.lru_width else 0,
            block_pattern=pattern,
            n_frontend_tokens=8 if self.n_frontend_tokens else 0,
            local_window=min(self.local_window, 16) if self.local_window else 0,
            attn_chunk=32,
            dtype="float32",
        )


# ---------------------------------------------------------------------------
# Input shapes assigned to every LM architecture.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def applicable_shapes(cfg: ModelConfig):
    """The assignment's skip rules (recorded in DESIGN.md §5)."""
    out = ["train_4k", "prefill_32k"]
    if not cfg.is_encoder_only:
        out.append("decode_32k")
        if cfg.family in ("ssm", "hybrid"):
            out.append("long_500k")   # sub-quadratic decode only
    return out


def skip_reason(cfg: ModelConfig, shape: str) -> Optional[str]:
    if shape in applicable_shapes(cfg):
        return None
    if cfg.is_encoder_only:
        return "encoder-only: no autoregressive decode step exists"
    return "pure full attention: 500k-token decode requires sub-quadratic attention"
