"""Architecture config registry: ``get_config(name)`` / ``--arch <id>``."""

from .base import ModelConfig, ShapeConfig, SHAPES, applicable_shapes, skip_reason

from .paligemma_3b import CONFIG as _paligemma
from .qwen2_1_5b import CONFIG as _qwen2
from .qwen2_5_3b import CONFIG as _qwen25
from .yi_6b import CONFIG as _yi
from .qwen3_14b import CONFIG as _qwen3
from .hubert_xlarge import CONFIG as _hubert
from .recurrentgemma_2b import CONFIG as _rgemma
from .kimi_k2 import CONFIG as _kimi
from .arctic_480b import CONFIG as _arctic
from .mamba2_130m import CONFIG as _mamba2

REGISTRY = {
    c.name: c
    for c in [
        _paligemma, _qwen2, _qwen25, _yi, _qwen3,
        _hubert, _rgemma, _kimi, _arctic, _mamba2,
    ]
}

ARCH_NAMES = list(REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    return REGISTRY[name]


__all__ = [
    "ARCH_NAMES", "ModelConfig", "REGISTRY", "SHAPES", "ShapeConfig",
    "applicable_shapes", "get_config", "skip_reason",
]
