"""Kimi K2: trillion-parameter MoE, 384 experts top-8 + 1 shared expert,
first layer dense (paper-table configuration with GQA attention as
assigned).  [arXiv:2501.kimi2; unverified]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=2048,                   # per-expert FFN width
    vocab=163840,
    n_experts=384,
    top_k=8,
    n_shared_experts=1,
    first_dense_layers=1,
    moe_dense_d_ff=16384,        # dense first-layer FFN (≈ top_k·d_ff)
    source="arXiv:2501.kimi2; unverified",
)
