"""Mamba2-130M: attention-free SSD (state-space duality).
[arXiv:2405.21060; unverified]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,                   # attention-free
    n_kv_heads=0,
    d_ff=0,                      # no MLP: the mamba block is the layer
    vocab=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_conv=4,
    tie_embeddings=True,
    source="arXiv:2405.21060; unverified",
)
