"""RecurrentGemma-2B (Griffin): RG-LRU recurrent blocks + local attention,
2:1 pattern, window 2048.  [arXiv:2402.19427; hf]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,                # MQA for the attention blocks
    d_head=256,
    d_ff=7680,
    vocab=256000,
    act="geglu",
    embed_scale=True,
    tie_embeddings=True,
    local_window=2048,
    block_pattern=("rec", "rec", "attn"),
    lru_width=2560,
    source="arXiv:2402.19427; hf",
)
