"""PaliGemma-3B: SigLIP patch frontend (stub) + Gemma-2B decoder backbone.
[arXiv:2407.07726; hf]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,                # MQA
    d_head=256,                  # gemma uses wide heads
    d_ff=16384,
    vocab=257216,
    act="geglu",
    embed_scale=True,
    tie_embeddings=True,
    frontend="patches",
    n_frontend_tokens=256,       # 224×224 / 14² SigLIP patches
    source="arXiv:2407.07726; hf",
)
