"""Snowflake Arctic: 128-expert top-2 MoE with a dense residual MLP in
parallel on every layer.  [hf:Snowflake/snowflake-arctic-base; hf]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_head=128,
    d_ff=4864,                   # per-expert FFN width
    vocab=32000,
    n_experts=128,
    top_k=2,
    moe_dense_residual=True,
    moe_dense_d_ff=4864,
    source="hf:Snowflake/snowflake-arctic-base; hf",
)
