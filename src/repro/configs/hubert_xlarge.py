"""HuBERT-XLarge: encoder-only audio transformer; the conv feature extractor
is a stub (input_specs provides frame embeddings).  [arXiv:2106.07447]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,               # full MHA
    d_head=80,
    d_ff=5120,
    vocab=504,                   # masked-prediction cluster targets
    causal=False,                # encoder-only: no decode shapes
    act="gelu",
    frontend="frames",
    source="arXiv:2106.07447; unverified",
)
