"""Calibrated cost model: loop-aware FLOPs / bytes / collective volumes.

``compiled.cost_analysis()`` (and text-parsed collective bytes) count a
``while``-loop body **once** — measured directly in this repo: a qwen2
train_4k probe reports 1.298e12 FLOPs at 2, 4 and 8 layers alike.  A step
with scanned layers and scanned microbatches therefore under-reports by
~L·n_mb.  We recover the true per-step cost from *unrolled* probe compiles
(``cfg.unroll_layers=True`` replaces the layer scan with a python loop, so
per-layer cost is visible) and the exact linear structure:

  counted_unrolled(L, B) = OUT + MB(B) + L·LY(B)
  true(L, B_mb, n_mb)    = OUT + n_mb · (MB(B_mb) + L·LY(B_mb))

where OUT = outside both loops (optimizer update, grad reduction), MB =
per-microbatch fixed part (embed, unembed, loss), LY = one layer.  MB and LY
are linear in batch; OUT is batch-independent.  Three probes identify all
three terms per metric:

  P_a  = (L=la, B=B0)     P_b = (L=lb, B=B0)     P_a2 = (L=la, B=2·B0)

  LY(B0) = (P_b − P_a)/(lb − la)
  MB(B0) = (P_a2 − P_a) − la·LY(B0)
  OUT    = 2·P_a − P_a2

Serving steps (no optimizer/microbatch loop) use the same probes with
n_mb = 1.  Probe compiles are small (2–6 layers, 1/8 batch), seconds each.
"""

from __future__ import annotations

import json
import os
from typing import Dict

from .analyze import HW, collective_bytes, roofline_terms

METRICS = ("flops", "bytes", "coll")


def _probe(arch: str, shape_name: str, mesh, **kw) -> Dict[str, float]:
    from repro.launch.dryrun import build_cell
    lower_fn, meta = build_cell(
        arch, shape_name, mesh,
        cfg_overrides={"unroll_layers": True}, **kw)
    compiled = lower_fn().compile()
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(coll["weighted_bytes"]),
    }


def _probe_layers(cfg) -> tuple:
    """(la, lb) respecting layer-group structure (hybrid patterns, leading
    dense layers)."""
    g = len(cfg.block_pattern) if cfg.block_pattern else 1
    base = cfg.first_dense_layers
    la = base + g * (2 if g == 1 else 1)
    lb = base + g * (4 if g == 1 else 2)
    return la, lb


def calibrate_cell(arch: str, shape_name: str, mesh,
                   microbatches: int = 8) -> Dict:
    from repro.configs import SHAPES, get_config
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    L = cfg.n_layers
    la, lb = _probe_layers(cfg)
    train = shape.kind == "train"
    n_mb = microbatches if train else 1
    b_full = shape.global_batch
    b0 = max(b_full // n_mb, 1) if train else b_full
    # Batch probes need 2·B0 ≤ full batch and divisibility by the data axes.
    b2 = min(2 * b0, b_full) if train else b_full
    mbkw = {"microbatches": 1} if train else {}

    pa = _probe(arch, shape_name, mesh, n_layers=la, global_batch=b0, **mbkw)
    pb = _probe(arch, shape_name, mesh, n_layers=lb, global_batch=b0, **mbkw)
    if train and b2 > b0:
        pa2 = _probe(arch, shape_name, mesh, n_layers=la, global_batch=b2,
                     **mbkw)
    else:
        pa2 = None

    out: Dict = {"probe_layers": (la, lb), "n_mb": n_mb}
    for m in METRICS:
        ly = max((pb[m] - pa[m]) / (lb - la), 0.0)
        if pa2 is not None:
            mb_part = max((pa2[m] - pa[m]) / (b2 / b0 - 1.0) - la * ly, 0.0)
            outpart = max(pa[m] - mb_part - la * ly, 0.0)
        else:
            mb_part = max(pa[m] - la * ly, 0.0)
            outpart = 0.0
        out[m] = outpart + n_mb * (mb_part + L * ly)
        out[m + "_layer"] = ly
        out[m + "_mb_fixed"] = mb_part
        out[m + "_outside"] = outpart
    out["roofline"] = roofline_terms(out["flops"], out["bytes"], out["coll"])
    return out


def calibrate_and_update(arch: str, shape_name: str, mesh, art_dir: str,
                         tag: str = "single") -> Dict:
    """Write calibrated terms into the cell's dry-run artifact."""
    from repro.configs import SHAPES, get_config
    from .analyze import analytic_bytes_floor

    cal = calibrate_cell(arch, shape_name, mesh)
    fn = os.path.join(art_dir, f"{arch}__{shape_name}__{tag}.json")
    if not os.path.exists(fn):
        return {"calibrated": cal}
    with open(fn) as f:
        d = json.load(f)

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_chips = 1
    for v in d["mesh"].values():
        n_chips *= v
    tokens = shape.global_batch * (
        shape.seq_len if shape.kind != "decode" else 1)
    cache_b = 0
    if shape.kind != "train":
        cache_b = int(d["memory"]["argument_bytes"]) * n_chips  # incl. cache
    floor = analytic_bytes_floor(
        shape.kind, n_params=d["n_params"], n_active=d["n_params_active"],
        n_layers=cfg.n_layers, d_model=cfg.d_model, vocab=cfg.vocab,
        tokens=tokens, n_mb=cal["n_mb"], n_chips=n_chips,
        cache_bytes=cache_b,
        opt_bytes_per_param=4 if "int8" in d.get("optimizer", "") else 16)
    cal["bytes_floor"] = floor
    cal["memory_floor_s"] = floor / HW["hbm_bw"]
    r = cal["roofline"]
    bound_opt = max(r["compute_s"], cal["memory_floor_s"], r["collective_s"])
    cal["roofline_fraction_optimistic"] = (
        r["compute_s"] / bound_opt if bound_opt else 0.0)

    d["calibrated"] = cal
    mf = d.get("model_flops_per_device", 0.0)
    d["calibrated"]["useful_flop_ratio"] = (
        mf / cal["flops"] if cal["flops"] else 0.0)
    with open(fn, "w") as f:
        json.dump(d, f, indent=1)
    return d


def main():
    import argparse
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=512")
    from repro.configs import ARCH_NAMES, get_config, SHAPES, skip_reason
    from repro.launch.mesh import make_production_mesh

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=False)
    archs = [args.arch] if args.arch else ARCH_NAMES
    for arch in archs:
        cfg = get_config(arch)
        for sh in ([args.shape] if args.shape else list(SHAPES)):
            if skip_reason(cfg, sh):
                continue
            try:
                d = calibrate_and_update(arch, sh, mesh, args.out)
                c = d["calibrated"]
                r = c["roofline"]
                print(f"CAL {arch} {sh}: flops={c['flops']:.3e} "
                      f"bytes={c['bytes']:.3e} coll={c['coll']:.3e} "
                      f"dom={r['dominant']} "
                      f"frac={r['roofline_fraction']:.3f} "
                      f"useful={c.get('useful_flop_ratio', 0):.3f}",
                      flush=True)
            except Exception as e:
                print(f"CALFAIL {arch} {sh}: {e}", flush=True)


if __name__ == "__main__":
    main()
