"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from artifacts.

    PYTHONPATH=src python -m repro.roofline.report artifacts/dryrun
"""

from __future__ import annotations

import glob
import json
import os
import sys



def load(art_dir: str):
    rows = []
    for fn in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(fn) as f:
            rows.append(json.load(f))
    return rows


def fmt_bytes(b: float) -> str:
    return f"{b / 1e9:.2f}"


def dryrun_table(rows, mesh_tag: str) -> str:
    out = ["| arch | shape | compile_s | bytes/dev GB | fits 16GB | "
           "collective GB | FLOPs/dev | notes |",
           "|---|---|---|---|---|---|---|---|"]
    for d in rows:
        if d.get("skipped"):
            if mesh_tag == "single":
                out.append(f"| {d['arch']} | {d['shape']} | — | — | — | — | — "
                           f"| SKIP: {d['skipped']} |")
            continue
        if d.get("error"):
            out.append(f"| {d['arch']} | {d['shape']} | — | — | — | — | — "
                       f"| ERROR |")
            continue
        if ("multi" if d["multi_pod"] else "single") != mesh_tag:
            continue
        m = d["memory"]
        out.append(
            f"| {d['arch']} | {d['shape']} | {d['compile_s']} "
            f"| {fmt_bytes(m['per_device_bytes'])} "
            f"| {'yes' if m['fits_hbm'] else 'NO'} "
            f"| {d['collectives']['weighted_bytes'] / 1e9:.3f} "
            f"| {d['cost']['flops_per_device']:.3g} "
            f"| {d.get('optimizer', '')} |")
    return "\n".join(out)


def roofline_table(rows) -> str:
    """Calibrated (loop-aware) roofline terms; memory bracketed between the
    analytic floor and XLA's fusion-inflated 'bytes accessed'."""
    out = ["| arch | shape | compute_s | mem_s floor…hlo | collective_s "
           "| dominant | frac (floor…hlo) | useful-FLOP | next lever |",
           "|---|---|---|---|---|---|---|---|---|"]
    for d in rows:
        if d.get("skipped") or d.get("error") or d.get("multi_pod"):
            continue
        c = d.get("calibrated")
        if c:
            r = c["roofline"]
            uf = c.get("useful_flop_ratio", d.get("useful_flop_ratio", 0.0))
            mem = f"{c.get('memory_floor_s', 0):.3g}…{r['memory_s']:.3g}"
            frac = (f"{c.get('roofline_fraction_optimistic', 0):.3f}…"
                    f"{r['roofline_fraction']:.3f}")
        else:
            r = d["roofline"]
            uf = d.get("useful_flop_ratio", 0.0)
            mem = f"{r['memory_s']:.3g}"
            frac = f"{r['roofline_fraction']:.3f}"
        out.append(
            f"| {d['arch']} | {d['shape']} | {r['compute_s']:.4g} "
            f"| {mem} | {r['collective_s']:.4g} "
            f"| {r['dominant']} | {frac} "
            f"| {uf:.3f} | {_hint(d)} |")
    return "\n".join(out)


def _hint(d) -> str:
    r = d["roofline"]
    dom = r["dominant"]
    if dom == "collective":
        kinds = d["collectives"]["bytes_by_kind"]
        top = max(kinds, key=kinds.get)
        return (f"cut {top} volume (top kind, "
                f"{kinds[top] / 1e9:.2f} GB): reshard or overlap")
    if dom == "memory":
        if d["kind"] == "decode":
            return "decode is weight/cache-streaming bound: batch more reqs"
        return "fuse/remat less, bf16 more intermediates"
    return "compute-bound: already near the right wall; raise utilisation"


def main():
    art = sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun"
    rows = load(art)
    print("## Dry-run — single pod (16×16)\n")
    print(dryrun_table(rows, "single"))
    print("\n## Dry-run — multi-pod (2×16×16)\n")
    print(dryrun_table(rows, "multi"))
    print("\n## Roofline (single pod)\n")
    print(roofline_table(rows))


if __name__ == "__main__":
    main()
