from .analyze import collective_bytes, roofline_terms, HW

__all__ = ["HW", "collective_bytes", "roofline_terms"]
