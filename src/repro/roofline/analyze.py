"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds:

  compute    = HLO_FLOPs / peak_FLOPs          (cost_analysis is per-device
                                                under SPMD, so chips cancel)
  memory     = HLO_bytes / HBM_bw
  collective = collective_bytes / link_bw

``collective_bytes`` is parsed from the post-optimisation HLO: every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
op's **output** bytes (per device), with all-reduce weighted 2× (its
ring/tree realisation moves ~2× the payload: reduce-scatter + all-gather).
"""

from __future__ import annotations

import re
from typing import Dict

# TPU v5e hardware constants (per the assignment).
HW = {
    "peak_flops": 197e12,      # bf16 FLOP/s per chip
    "hbm_bw": 819e9,           # B/s per chip
    "link_bw": 50e9,           # B/s per ICI link
    "hbm_bytes": 16 * 1024**3, # HBM capacity per chip
}

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# `%name = TYPE kind(...)` — TYPE may be a tuple `(bf16[..], f32[..])`.
_OP_RE = re.compile(
    r"=\s+(?P<type>\([^)]*\)|\S+)\s+"
    r"(?P<kind>all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-kind per-device collective output bytes from HLO text."""
    out = {k: 0 for k in _COLL_KINDS}
    count = {k: 0 for k in _COLL_KINDS}
    for m in _OP_RE.finditer(hlo_text):
        kind = m.group("kind").replace("-start", "")
        nbytes = _type_bytes(m.group("type"))
        out[kind] += nbytes
        count[kind] += 1
    return {
        "bytes_by_kind": out,
        "count_by_kind": count,
        "weighted_bytes": sum(
            b * (2 if k == "all-reduce" else 1) for k, b in out.items()),
    }


def roofline_terms(flops: float, bytes_accessed: float,
                   coll_bytes: float) -> Dict[str, float]:
    """All inputs per-device; returns seconds per term + the bottleneck."""
    t_c = flops / HW["peak_flops"]
    t_m = bytes_accessed / HW["hbm_bw"]
    t_x = coll_bytes / HW["link_bw"]
    terms = {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x}
    dominant = max(terms, key=terms.get)
    bound = max(t_c, t_m, t_x)
    terms["dominant"] = dominant.replace("_s", "")
    terms["step_lower_bound_s"] = bound
    terms["roofline_fraction"] = (t_c / bound) if bound > 0 else 0.0
    return terms


def analytic_bytes_floor(kind: str, *, n_params: int, n_active: int,
                         n_layers: int, d_model: int, vocab: int,
                         tokens: int, n_mb: int, n_chips: int,
                         cache_bytes: int = 0, opt_bytes_per_param: int = 16,
                         param_bytes: int = 2) -> float:
    """Physical lower bound on per-device HBM traffic for one step.

    XLA's ``bytes accessed`` sums every op's operand/output bytes and so
    over-counts fused intermediates several-fold; this floor counts only the
    unavoidable streams: parameter reads (per microbatch, fwd+bwd), gradient
    and optimizer-state read/write, saved layer activations (write + read),
    logits, and KV/state-cache traffic for serving.  True HBM time lies
    between this floor and the HLO figure.
    """
    p_loc = n_params / n_chips
    act_loc = n_active / n_chips
    tok_loc = tokens / n_chips
    if kind == "train":
        # fwd+bwd param reads per microbatch (active params only for MoE),
        # grad accum rw, opt state rw, param update rw.
        b = 2 * act_loc * param_bytes * 2 * n_mb
        b += p_loc * (4 * 2 + opt_bytes_per_param)      # grads + m/v
        b += p_loc * param_bytes * 2                     # param update
        b += n_layers * tok_loc * d_model * 2 * 2        # residuals w+r
        b += (tokens * vocab * 4 / n_chips) * 2          # f32 logits w+r
        return b
    # serving: one param read + cache traffic (+ logits for prefill)
    b = act_loc * param_bytes
    b += cache_bytes / n_chips * (2 if kind == "prefill" else 1)
    if kind == "prefill":
        b += tokens * d_model * 2 / n_chips * 2 * n_layers
    return b


def model_flops(cfg, shape, n_params_active: float) -> float:
    """MODEL_FLOPS: 6·N·D train (fwd+bwd), 2·N·D forward-only."""
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_params_active * tokens


def count_params(shapes_tree) -> int:
    import jax
    return sum(int(_prod(l.shape)) for l in jax.tree.leaves(shapes_tree))


def active_param_fraction(cfg) -> float:
    """Fraction of parameters active per token (MoE: top-k of experts)."""
    if not cfg.is_moe:
        return 1.0
    # expert params active = top_k / n_experts of the expert weights; the
    # rest (attention, embeddings, shared, dense) are always active.
    return -1.0  # computed precisely in dryrun from param group sizes


def _prod(t) -> int:
    out = 1
    for x in t:
        out *= int(x)
    return out
