"""Deterministic synthetic data pipeline.

Tokens are a hash of (stream seed, step, position) so every host can generate
its own shard without communication, restarts are reproducible from the step
counter alone (no data-state checkpoints needed), and elastic re-sharding is
trivial — exactly the data-pipeline properties a 1000-node deployment needs.
The global shuffle used by the PSRS example goes through
``repro.pems_apps.psrs_sort`` (the thesis' own application).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab: int
    seed: int = 0
    frontend: str = "none"           # none | patches | frames
    n_frontend_tokens: int = 0
    d_model: int = 0                 # for frontend embedding stubs


def _hash_tokens(seed, step, b, s, vocab) -> jnp.ndarray:
    """Stateless splitmix-style token generator on device."""
    i = (jnp.arange(b, dtype=jnp.uint32)[:, None] * jnp.uint32(2654435761)
         + jnp.arange(s, dtype=jnp.uint32)[None, :] * jnp.uint32(40503)
         + jnp.uint32(step) * jnp.uint32(374761393)
         + jnp.uint32(seed))
    i = (i ^ (i >> 15)) * jnp.uint32(2246822519)
    i = (i ^ (i >> 13)) * jnp.uint32(3266489917)
    i = i ^ (i >> 16)
    return (i % jnp.uint32(vocab)).astype(jnp.int32)


def synthetic_batch(cfg: DataConfig, step: int) -> Dict[str, jnp.ndarray]:
    b, s = cfg.global_batch, cfg.seq_len
    if cfg.frontend == "frames":
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
        return {
            "frames": jax.random.normal(key, (b, s, cfg.d_model), jnp.float32),
            "labels": _hash_tokens(cfg.seed + 1, step, b, s, cfg.vocab),
        }
    s_text = s - (cfg.n_frontend_tokens if cfg.frontend == "patches" else 0)
    out = {"tokens": _hash_tokens(cfg.seed, step, b, s_text, cfg.vocab)}
    if cfg.frontend == "patches":
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
        out["patches"] = jax.random.normal(
            key, (b, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
    return out


def synthetic_batches(cfg: DataConfig, start_step: int = 0) -> Iterator[Dict]:
    step = start_step
    while True:
        yield synthetic_batch(cfg, step)
        step += 1


def make_batch_specs(cfg: DataConfig, dtype=jnp.bfloat16) -> Dict:
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
    b, s = cfg.global_batch, cfg.seq_len
    if cfg.frontend == "frames":
        return {
            "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), dtype),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
    s_text = s - (cfg.n_frontend_tokens if cfg.frontend == "patches" else 0)
    out = {"tokens": jax.ShapeDtypeStruct((b, s_text), jnp.int32)}
    if cfg.frontend == "patches":
        out["patches"] = jax.ShapeDtypeStruct(
            (b, cfg.n_frontend_tokens, cfg.d_model), dtype)
    return out
