"""Rule ``block-api-only``: raw byte-level disk access stays in the io
layer.

Every byte of backing data is supposed to flow through the block API
(``read_block``/``write_block`` on a backing, or the ``field*``/
``with_field*`` store accessors above it) so :class:`repro.core.iostats.
IOLedger` measured counters stay comparable to the Lemma 7.1.7/7.1.9
modeled ones.  A stray ``np.memmap``/binary ``open()``/``os.pread`` outside
``repro/io/`` + ``core/backing.py`` moves bytes the ledger never sees —
exactly the drift this rule exists to stop.  Durable-state helpers
(cursor/snapshot writes in ``core/recovery.py``) carry audited per-line
suppressions instead: their bytes are control state, not backing data.
"""

from __future__ import annotations

import ast
from typing import Iterator

from ..astutil import dotted, keyword_arg, open_mode_is_binary
from ..engine import FileContext, Finding, Rule

# Paths allowed to touch bytes directly: the driver/engine layer itself and
# the backing that adapts it to the block API.
_ALLOWED = ("repro/io/", "core/backing.py")

_RAW_OS = {"os.open", "os.pread", "os.preadv", "os.pwrite", "os.pwritev"}
_MEMMAP = {"np.memmap", "numpy.memmap",
           "np.lib.format.open_memmap", "numpy.lib.format.open_memmap"}
_NP_LOAD = {"np.load", "numpy.load"}


class BlockApiOnly(Rule):
    name = "block-api-only"
    summary = ("raw open()/np.memmap/os.pread-style disk access outside "
               "repro/io/ + core/backing.py bypasses IOLedger accounting")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        if ctx.path_is_under(*_ALLOWED):
            return
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = dotted(node.func)
            if name in _RAW_OS or name in _MEMMAP:
                yield self.finding(
                    ctx, node,
                    f"raw disk access '{name}' outside the io layer — "
                    "route through the block API (backing read_block/"
                    "write_block) or a repro.io helper so the transfer is "
                    "ledger-accounted")
            elif name == "open" and open_mode_is_binary(node):
                yield self.finding(
                    ctx, node,
                    "binary open() outside the io layer — backing bytes "
                    "must flow through the block API; durable control "
                    "state belongs in repro.core.recovery's atomic "
                    "helpers")
            elif name in _NP_LOAD:
                mm = keyword_arg(node, "mmap_mode")
                if mm is not None and not (isinstance(mm, ast.Constant)
                                           and mm.value is None):
                    yield self.finding(
                        ctx, node,
                        "np.load(mmap_mode=...) maps a file outside the io "
                        "layer — use repro.io.npyio.load_npy_mmap (or the "
                        "block API) so raw mappings stay auditable")
