"""Rule ``atomic-durability``: renames of durable state are fsync-dominated.

The durability protocol (PR 6's checkpoint manifests and superstep cursor)
is temp-write → ``fsync`` the temp file → ``os.replace`` → ``fsync`` the
directory.  A rename with no fsync anywhere before it in the same function
publishes a name whose *contents* may still be in the page cache — a crash
then yields exactly the torn state the atomic rename was supposed to
prevent.  The check is lexical and per-scope: any ``os.replace``/
``os.rename`` must be preceded (by line) in its function by an fsync-like
call (``os.fsync``, ``fsync_dir``, ``fsync_file``, an ``.fsync()`` method,
or one of the ``atomic_*`` recovery helpers that fsync internally).
"""

from __future__ import annotations

import ast
from typing import Iterator, List

from ..astutil import dotted, function_scopes, scope_calls
from ..engine import FileContext, Finding, Rule

_RENAMES = {"os.replace", "os.rename"}
# A call satisfying durability when it appears earlier in the same scope.
_FSYNC_NAMES = {"fsync", "fsync_dir", "fsync_file",
                "atomic_write_json", "atomic_replace_file"}


def _last_name(node: ast.expr) -> str:
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return ""


class AtomicDurability(Rule):
    name = "atomic-durability"
    summary = ("os.replace/os.rename without a preceding fsync in the same "
               "function can publish torn durable state after a crash")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for scope in function_scopes(ctx.tree):
            renames: List[ast.Call] = []
            fsync_lines: List[int] = []
            for call in scope_calls(scope):
                name = dotted(call.func)
                if name in _RENAMES:
                    renames.append(call)
                elif _last_name(call.func) in _FSYNC_NAMES:
                    fsync_lines.append(call.lineno)
            for call in renames:
                if not any(ln < call.lineno for ln in fsync_lines):
                    yield self.finding(
                        ctx, call,
                        f"{dotted(call.func)} with no fsync earlier in the "
                        "same function — durable state must be written "
                        "temp + fsync + atomic rename (+ directory fsync); "
                        "use repro.core.recovery.atomic_replace_file / "
                        "atomic_write_json")
