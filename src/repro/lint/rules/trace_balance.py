"""Rule ``trace-balance``: every ``tracer.begin`` closes in its scope.

The :mod:`repro.obs` tracer's ``begin``/``end`` primitives emit raw "B"/"E"
events; a ``begin`` that never meets its ``end`` leaves a dangling span the
exporter has to synthesize a close for (:func:`repro.obs.export._balanced`)
— the trace stays loadable, but the span's duration is a guess.  The
``span()`` context manager cannot leak (``__exit__`` always completes the
span), so the invariant is: prefer ``span()``; where raw ``begin`` is
needed, the matching ``end`` must be reachable in the *same* scope.

Intraprocedural, source-line order per scope: a call whose receiver's last
dotted component is ``tracer`` (``self.tracer``, ``tracer``, ``TRACER``,
``self._tracer``) and whose method is ``begin`` pushes; ``end`` pops the
innermost open begin.  Begins still open at scope end are findings.  A bare
``end`` with no open begin is ignored — deliberate cross-method pairs
(e.g. a cursor's ``mark_in_progress``/``mark_completed``) keep their
``end`` side clean and suppress the ``begin`` side with an audit comment.
"""

from __future__ import annotations

import ast
from typing import Iterator, List, Optional

from ..astutil import function_scopes
from ..engine import FileContext, Finding, Rule

_METHODS = {"begin", "end"}


def _tracer_method(call: ast.Call) -> Optional[str]:
    """``"begin"``/``"end"`` when ``call`` is ``<...>.tracer.begin(...)``
    (or ``end``) with a tracer-named receiver, else None."""
    func = call.func
    if not isinstance(func, ast.Attribute) or func.attr not in _METHODS:
        return None
    recv = func.value
    if isinstance(recv, ast.Attribute):
        last = recv.attr
    elif isinstance(recv, ast.Name):
        last = recv.id
    else:
        return None
    if last.lower().lstrip("_") != "tracer":
        return None
    return func.attr


class TraceBalance(Rule):
    name = "trace-balance"
    summary = ("every tracer.begin(...) in a scope needs a matching "
               "tracer.end(...) — or use the span() context manager")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for scope in function_scopes(ctx.tree):
            yield from self._check_scope(ctx, scope)

    def _calls(self, scope: ast.AST) -> List[ast.Call]:
        """Tracer begin/end calls in source order, nested defs excluded."""
        out: List[ast.Call] = []
        stack: List[ast.AST] = list(scope.body)
        while stack:
            node = stack.pop()
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if isinstance(node, ast.Call) and _tracer_method(node):
                out.append(node)
            stack.extend(ast.iter_child_nodes(node))
        out.sort(key=lambda c: (c.lineno, c.col_offset))
        return out

    def _check_scope(self, ctx: FileContext, scope: ast.AST
                     ) -> Iterator[Finding]:
        open_begins: List[ast.Call] = []
        for call in self._calls(scope):
            if _tracer_method(call) == "begin":
                open_begins.append(call)
            elif open_begins:
                open_begins.pop()
        for call in open_begins:
            yield self.finding(
                ctx, call,
                "tracer.begin(...) with no matching tracer.end(...) in "
                "this scope — the span dangles until the exporter "
                "synthesizes a close; use the span() context manager, or "
                "end it in the same scope")
