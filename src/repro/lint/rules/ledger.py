"""Rule ``ledger-balance``: every block transfer is accounted exactly once.

Two failure shapes, both per function scope:

* **missing** — a direct ``read_block``/``write_block`` call with no ledger
  accounting anywhere in the function.  The backing's block API moves raw
  bytes; the convention (see ``core/collectives.py``) is that direct
  callers pair each transfer with ``_account_disk``/``add_disk_*``/
  ``add_tier_*``.
* **double-count** — a function that reaches data through the
  *self-accounting* store accessors (``field``/``field_rows``/
  ``with_field``/``with_field_rows``, which bill the ledger internally via
  ``TieredStore._account``) *and* manually bumps ``add_disk_read``/
  ``add_disk_write``: the same bytes billed twice, breaking the
  measured-vs-modeled comparisons the experiment tables pin.

``core/backing.py`` and ``repro/io/`` are exempt — they *implement* the
accounting.
"""

from __future__ import annotations

import ast
from typing import Iterator, List

from ..astutil import function_scopes, scope_calls
from ..engine import FileContext, Finding, Rule

_ALLOWED = ("repro/io/", "core/backing.py")

_BLOCK = {"read_block", "write_block"}
_ACCOUNTING = {"add_disk_read", "add_disk_write", "add_tier_in",
               "add_tier_out", "_account", "_account_disk"}
_SELF_ACCOUNTING = {"field", "field_rows", "with_field", "with_field_rows"}
_MANUAL_DISK = {"add_disk_read", "add_disk_write"}


def _attr(call: ast.Call) -> str:
    if isinstance(call.func, ast.Attribute):
        return call.func.attr
    if isinstance(call.func, ast.Name):
        return call.func.id
    return ""


class LedgerBalance(Rule):
    name = "ledger-balance"
    summary = ("block-API transfers must be ledger-accounted exactly once: "
               "no unaccounted read_block/write_block, no manual add_disk_* "
               "next to self-accounting store accessors")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        if ctx.path_is_under(*_ALLOWED):
            return
        for scope in function_scopes(ctx.tree):
            blocks: List[ast.Call] = []
            manual: List[ast.Call] = []
            has_acct = False
            has_self_acct = False
            for call in scope_calls(scope):
                a = _attr(call)
                if a in _BLOCK:
                    blocks.append(call)
                if a in _ACCOUNTING:
                    has_acct = True
                if a in _SELF_ACCOUNTING:
                    has_self_acct = True
                if a in _MANUAL_DISK:
                    manual.append(call)
            if blocks and not has_acct:
                yield self.finding(
                    ctx, blocks[0],
                    f"direct {_attr(blocks[0])} with no ledger accounting "
                    "in this function — pair the transfer with "
                    "_account_disk/add_disk_*/add_tier_* (see "
                    "core/collectives.py for the convention), or reach the "
                    "data through the self-accounting store accessors")
            if has_self_acct and manual:
                yield self.finding(
                    ctx, manual[0],
                    f"manual {_attr(manual[0])} in a function that also "
                    "uses self-accounting store accessors (field*/"
                    "with_field* bill the ledger internally) — the same "
                    "bytes would be counted twice")
