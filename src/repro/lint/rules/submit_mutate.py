"""Rule ``submit-then-mutate``: buffers stay frozen while a request flies.

``IOEngine.submit_read``/``submit_write`` return immediately; the worker
touches the buffer *later*.  Mutating the buffer (or submitting the same
byte range again) before the matching ``wait``/``drain``/``poll`` is a
data race the engine cannot see — the exact hazard class the asynchronous
I/O refinement introduces, and the reason the runtime twin
(``io_driver="sanitize:<inner>"``, :mod:`repro.io.sanitize`) exists.

Intraprocedural, single-pass dataflow in source-line order: a submit
registers its buffer expression; a barrier (``wait``/``drain``/``poll``/
``fsync``/``close`` on anything) clears all registrations; in between, the
rule flags

* in-place mutation of a tracked base name (``buf[...] = ...``,
  ``buf += ...``, ``buf.fill(...)``, ``np.copyto(buf, ...)``) when the
  whole name was submitted or the identical subscript expression was,
* re-submission of the *identical* buffer expression (overlapping
  in-flight requests on the same range).

Loop back-edges are not modeled — disjoint chunked submit loops (the
``FileBacking._read_rows`` pattern) stay clean; the runtime sanitizer
covers the dynamic cases.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass
from typing import Iterator, List, Optional, Tuple

from ..astutil import base_name, dotted, function_scopes, normalize
from ..engine import FileContext, Finding, Rule

_SUBMITS = {"submit_read", "submit_write"}
_BARRIERS = {"wait", "drain", "poll", "fsync", "close"}
_MUTATING_METHODS = {"fill", "sort", "put", "byteswap", "partition",
                     "resize", "setfield"}


@dataclass
class _InFlight:
    op: str
    base: Optional[str]     # leftmost name of the buffer expression
    fingerprint: str        # normalize() of the buffer expression
    whole_name: bool        # the bare name itself was submitted
    line: int


def _buffer_arg(call: ast.Call) -> Optional[ast.expr]:
    # submit_read(offset, out) / submit_write(offset, data): buffer is the
    # second positional or the out=/data= keyword.
    if len(call.args) >= 2:
        return call.args[1]
    for kw in call.keywords:
        if kw.arg in ("out", "data"):
            return kw.value
    return None


class SubmitThenMutate(Rule):
    name = "submit-then-mutate"
    summary = ("a buffer handed to submit_read/submit_write must not be "
               "mutated or re-submitted before the matching "
               "wait/drain/poll")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for scope in function_scopes(ctx.tree):
            yield from self._check_scope(ctx, scope)

    # ------------------------------------------------------------------ scope
    def _events(self, scope: ast.AST) -> List[Tuple[int, int, ast.AST]]:
        """Relevant nodes in source order, nested defs excluded."""
        out: List[Tuple[int, int, ast.AST]] = []
        stack: List[ast.AST] = list(scope.body)
        while stack:
            node = stack.pop()
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if isinstance(node, (ast.Call, ast.Assign, ast.AugAssign)):
                out.append((node.lineno, node.col_offset, node))
            stack.extend(ast.iter_child_nodes(node))
        out.sort(key=lambda t: (t[0], t[1]))
        return out

    def _check_scope(self, ctx: FileContext, scope: ast.AST
                     ) -> Iterator[Finding]:
        tracked: List[_InFlight] = []
        for _, _, node in self._events(scope):
            if isinstance(node, ast.Call):
                attr = (node.func.attr
                        if isinstance(node.func, ast.Attribute) else "")
                if attr in _BARRIERS:
                    tracked.clear()
                elif attr in _SUBMITS:
                    yield from self._on_submit(ctx, node, attr, tracked)
                elif attr in _MUTATING_METHODS:
                    yield from self._on_mutation(
                        ctx, node, base_name(node.func.value),
                        f".{attr}(...)", tracked)
                elif dotted(node.func) in ("np.copyto", "numpy.copyto") \
                        and node.args:
                    yield from self._on_mutation(
                        ctx, node, base_name(node.args[0]), "np.copyto",
                        tracked)
            else:
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    if isinstance(t, ast.Subscript):
                        yield from self._on_store(ctx, node, t, tracked)
                    elif (isinstance(node, ast.AugAssign)
                            and isinstance(t, ast.Name)):
                        # buf += x mutates ndarrays in place.
                        yield from self._on_mutation(
                            ctx, node, t.id, "augmented assignment", tracked)

    # ------------------------------------------------------------------ events
    def _on_submit(self, ctx: FileContext, call: ast.Call, attr: str,
                   tracked: List[_InFlight]) -> Iterator[Finding]:
        buf = _buffer_arg(call)
        if buf is None:
            return
        fp = normalize(buf)
        for t in tracked:
            if t.fingerprint == fp and "write" in (t.op, attr.split("_")[1]):
                yield self.finding(
                    ctx, call,
                    f"re-submission of the buffer range already in flight "
                    f"from {attr.split('_')[1]} submit at line {t.line} — "
                    "overlapping unserialized requests race; wait/drain "
                    "the first request before resubmitting")
        tracked.append(_InFlight(
            op=attr.split("_")[1], base=base_name(buf), fingerprint=fp,
            whole_name=isinstance(buf, ast.Name), line=call.lineno))

    def _on_store(self, ctx: FileContext, node: ast.AST, tgt: ast.Subscript,
                  tracked: List[_InFlight]) -> Iterator[Finding]:
        base = base_name(tgt)
        fp = normalize(tgt)
        for t in tracked:
            if t.base is not None and t.base == base and (
                    t.whole_name or t.fingerprint == fp):
                yield self.finding(
                    ctx, node,
                    f"write to '{base}[...]' while a {t.op} of it "
                    f"submitted at line {t.line} is still in flight — "
                    "wait/drain first (runtime twin: "
                    "io_driver='sanitize:<inner>')")
                return

    def _on_mutation(self, ctx: FileContext, node: ast.AST,
                     base: Optional[str], what: str,
                     tracked: List[_InFlight]) -> Iterator[Finding]:
        if base is None:
            return
        for t in tracked:
            if t.base == base and t.whole_name:
                yield self.finding(
                    ctx, node,
                    f"{what} mutates '{base}' while a {t.op} of it "
                    f"submitted at line {t.line} is still in flight — "
                    "wait/drain first (runtime twin: "
                    "io_driver='sanitize:<inner>')")
                return
