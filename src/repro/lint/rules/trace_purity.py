"""Rule ``trace-purity``: stage functions reaching the jit cache stay pure.

The tiered executor caches one jitted body per stage-function identity
(``_tiered_body``), so a stage function's Python body runs **once at trace
time**, not once per superstep.  Any Python-level side effect inside it —
an ``IOLedger``/``TierStats`` bump, host I/O, a ``.item()`` host sync, a
mutation of a closed-over object — either silently happens exactly once
(wrong counters) or defeats the cache and retraces every call (the
1.23 s-per-superstep regression PR 8's cache fixed).  Stage functions are
found syntactically: any local function passed by name as an argument to a
``*.superstep(...)`` call.
"""

from __future__ import annotations

import ast
from typing import Iterator, Set

from ..astutil import dotted, local_functions
from ..engine import FileContext, Finding, Rule

_HOST_CALLS = {"print", "open", "input"}
_HOST_PREFIXES = ("os.", "time.", "np.save", "numpy.save", "np.load",
                  "numpy.load", "np.fromfile", "numpy.fromfile")


class TracePurity(Rule):
    name = "trace-purity"
    summary = ("Python side effects (ledger bumps, host I/O, .item(), "
               "attribute mutation) inside stage functions run at trace "
               "time only, or force a retrace per superstep")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        defs = local_functions(ctx.tree)
        stage_fns: Set[ast.AST] = set()
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "superstep"):
                continue
            for arg in node.args:
                if isinstance(arg, ast.Name) and arg.id in defs:
                    stage_fns.update(defs[arg.id])
        for fn in stage_fns:
            yield from self._check_stage(ctx, fn)

    def _check_stage(self, ctx: FileContext, fn: ast.AST
                     ) -> Iterator[Finding]:
        where = f"stage function '{fn.name}'"
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                if (isinstance(node.func, ast.Attribute)
                        and node.func.attr == "item"):
                    yield self.finding(
                        ctx, node,
                        f".item() inside {where} forces a host sync at "
                        "trace time — return the value and reduce outside "
                        "the staged body")
                    continue
                name = dotted(node.func) or ""
                if name in _HOST_CALLS or name.startswith(_HOST_PREFIXES):
                    yield self.finding(
                        ctx, node,
                        f"host call '{name}' inside {where} runs once at "
                        "trace time, not per superstep — hoist it out of "
                        "the staged body")
                elif (isinstance(node.func, ast.Attribute)
                        and node.func.attr.startswith("add_")):
                    yield self.finding(
                        ctx, node,
                        f"ledger/stats bump '.{node.func.attr}(...)' inside "
                        f"{where} fires at trace time only — account in "
                        "the executor (e.g. _ledger_superstep), never in "
                        "the staged body")
            elif isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    if isinstance(t, ast.Attribute):
                        yield self.finding(
                            ctx, t,
                            f"attribute mutation inside {where} is a "
                            "Python side effect the jit cache will not "
                            "replay — stage functions must be pure")
