"""The six repo-grown rules, one module per rule.

``ALL_RULES`` is the registry the CLI and tests iterate; rule ids are the
strings used in suppression comments and the baseline file.
"""

from .block_api import BlockApiOnly
from .durability import AtomicDurability
from .ledger import LedgerBalance
from .submit_mutate import SubmitThenMutate
from .trace_balance import TraceBalance
from .trace_purity import TracePurity

ALL_RULES = (
    BlockApiOnly(),
    AtomicDurability(),
    LedgerBalance(),
    TracePurity(),
    SubmitThenMutate(),
    TraceBalance(),
)

__all__ = ["ALL_RULES", "AtomicDurability", "BlockApiOnly", "LedgerBalance",
           "SubmitThenMutate", "TraceBalance", "TracePurity"]
