"""pems-lint — repo-invariant static analysis for the PEMS2 codebase.

The conventions that keep the out-of-core path correct are not Python
semantics, so no general-purpose linter checks them: every byte of backing
data flows through the block API (else :class:`~repro.core.iostats.IOLedger`
accounting silently drifts from the Lemma 7.1.7/7.1.9 closed forms), durable
state is written temp + ``fsync`` + atomic rename, ledger accounting happens
exactly once per transfer, stage functions that reach the executor's jit
cache are side-effect free, and buffers handed to the async
:class:`~repro.io.engine.IOEngine` are not touched while a request is in
flight.

``python -m repro.lint <paths>`` runs one AST visitor pass per rule over
every ``.py`` file under the given paths.  Findings are suppressed per line
with ``# pems-lint: disable=<rule>[,<rule>|all]`` (same line, or a
comment-only line directly above) or grandfathered via a committed JSON
baseline (``pems_lint_baseline.json``); anything else fails the run.
``docs/ARCHITECTURE.md`` ("Invariants") records the incident behind each
rule.  The static ``submit-then-mutate`` rule has a runtime twin: the
``io_driver="sanitize:<inner>"`` wrapper (:mod:`repro.io.sanitize`).
"""

from .engine import Finding, LintError, Rule, lint_paths, load_baseline
from .rules import ALL_RULES

__all__ = [
    "ALL_RULES",
    "Finding",
    "LintError",
    "Rule",
    "lint_paths",
    "load_baseline",
]
