"""CLI: ``python -m repro.lint [paths...]`` (also ``scripts/pems_lint.py``).

Exit status 0 when every finding is suppressed or baselined, 1 otherwise,
2 on usage/parse errors.  ``--json`` emits a machine-readable report;
``--write-baseline`` grandfathers the current findings.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from .engine import LintError, lint_paths, load_baseline, save_baseline
from .rules import ALL_RULES

_DEFAULT_BASELINE = "pems_lint_baseline.json"


def main(argv: Optional[List[str]] = None) -> int:
    """Run the linter; returns the process exit status."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="pems-lint: repo-invariant static analysis "
                    "(see docs/ARCHITECTURE.md 'Invariants')")
    ap.add_argument("paths", nargs="*", default=["src", "scripts"],
                    help="files/directories to lint (default: src scripts)")
    ap.add_argument("--rules", default=None, metavar="R1,R2",
                    help="run only these rule ids")
    ap.add_argument("--list-rules", action="store_true",
                    help="print rule ids + summaries and exit")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit a JSON report instead of human lines")
    ap.add_argument("--baseline", default=None, metavar="FILE",
                    help="grandfather findings recorded in FILE "
                         f"(default: {_DEFAULT_BASELINE} if present)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write all current findings to the baseline file "
                         "and exit 0")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in ALL_RULES:
            print(f"{rule.name}: {rule.summary}")
        return 0

    rules = list(ALL_RULES)
    if args.rules:
        wanted = {r.strip() for r in args.rules.split(",") if r.strip()}
        known = {r.name for r in ALL_RULES}
        if not wanted <= known:
            print(f"pems-lint: unknown rule(s) {sorted(wanted - known)} "
                  f"(known: {sorted(known)})", file=sys.stderr)
            return 2
        rules = [r for r in ALL_RULES if r.name in wanted]

    baseline_path = args.baseline or _DEFAULT_BASELINE
    try:
        findings, suppressed = lint_paths(args.paths or ["src", "scripts"],
                                          rules)
        if args.write_baseline:
            save_baseline(baseline_path, findings)
            print(f"pems-lint: wrote {len(findings)} finding(s) to "
                  f"{baseline_path}")
            return 0
        baseline = load_baseline(args.baseline
                                 if args.baseline else baseline_path)
    except LintError as e:
        print(f"pems-lint: {e}", file=sys.stderr)
        return 2

    new = [f for f in findings if f.key() not in baseline]
    baselined = len(findings) - len(new)

    if args.as_json:
        print(json.dumps({"findings": [f.to_json() for f in new],
                          "baselined": baselined,
                          "suppressed": suppressed}, indent=2))
    else:
        for f in new:
            print(f.format())
        print(f"pems-lint: {len(new)} finding(s) "
              f"({baselined} baselined, {suppressed} suppressed)")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
