"""Rule engine: file collection, suppression comments, baseline filtering.

Stdlib-only (``ast``/``json``/``re``) so the CI job needs no install step —
the same property :mod:`scripts.check_docs` relies on.  Each rule is one
:class:`ast.NodeVisitor`-style pass; the engine parses every file once and
hands the tree to each rule through a :class:`FileContext`.
"""

from __future__ import annotations

import ast
import json
import os
import re
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

__all__ = ["Finding", "FileContext", "LintError", "Rule", "collect_files",
           "lint_paths", "load_baseline", "save_baseline"]

# Same-line (or comment-only line directly above) suppression:
#   x = open(p, "rb")  # pems-lint: disable=block-api-only
#   # pems-lint: disable=ledger-balance,atomic-durability
_SUPPRESS_RE = re.compile(r"#\s*pems-lint:\s*disable=([A-Za-z0-9_,\- ]+)")


class LintError(RuntimeError):
    """A file could not be linted (unreadable, syntax error)."""


@dataclass(frozen=True)
class Finding:
    """One rule violation at ``path:line:col``."""

    rule: str
    path: str
    line: int
    col: int
    message: str

    def key(self) -> Tuple[str, str, int]:
        """Identity for baseline matching: (rule, path, line)."""
        return (self.rule, self.path, self.line)

    def format(self) -> str:
        """The human-readable one-liner (``path:line:col: rule: message``)."""
        return f"{self.path}:{self.line}:{self.col}: {self.rule}: " \
               f"{self.message}"

    def to_json(self) -> dict:
        """JSON-serialisable dict (also the baseline entry shape)."""
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "message": self.message}


class Rule:
    """Base class: one named invariant checked by one AST pass.

    Subclasses set ``name`` (the id used in suppressions/baselines/CLI) and
    ``summary`` (one line for ``--list-rules`` and the docs), and implement
    :meth:`check` returning raw findings — the engine applies suppressions
    and the baseline afterwards.
    """

    name: str = ""
    summary: str = ""

    def check(self, ctx: "FileContext") -> Iterable[Finding]:
        raise NotImplementedError

    def finding(self, ctx: "FileContext", node: ast.AST,
                message: str) -> Finding:
        """A :class:`Finding` for this rule anchored at ``node``."""
        return Finding(self.name, ctx.path, getattr(node, "lineno", 1),
                       getattr(node, "col_offset", 0), message)


class FileContext:
    """One parsed file handed to every rule: path, source lines, AST, and
    the per-line suppression table."""

    def __init__(self, path: str, source: str):
        self.path = path.replace(os.sep, "/")
        self.source = source
        self.lines = source.splitlines()
        try:
            self.tree = ast.parse(source, filename=path)
        except SyntaxError as e:
            raise LintError(f"{path}: cannot parse: {e}") from e
        self._suppress: Dict[int, Set[str]] = {}
        for i, ln in enumerate(self.lines, 1):
            m = _SUPPRESS_RE.search(ln)
            if m:
                self._suppress[i] = {
                    r.strip() for r in m.group(1).split(",") if r.strip()}

    def path_is_under(self, *fragments: str) -> bool:
        """True when this file lives under any of the given path fragments
        (matched against the /-normalised path, e.g. ``"repro/io/"``)."""
        return any(f in self.path for f in fragments)

    def suppressed(self, rule: str, line: int) -> bool:
        """True when ``rule`` is disabled on ``line`` — by a trailing
        comment on the line itself, or by a comment-only line directly
        above it."""
        on_line = self._suppress.get(line)
        if on_line and (rule in on_line or "all" in on_line):
            return True
        above = self._suppress.get(line - 1)
        if above and (rule in above or "all" in above):
            text = self.lines[line - 2].lstrip() if line >= 2 else ""
            return text.startswith("#")
        return False


def collect_files(paths: Sequence[str]) -> List[str]:
    """Expand files/directories into a sorted list of ``.py`` files,
    skipping hidden directories and ``__pycache__``."""
    out: Set[str] = set()
    for p in paths:
        if os.path.isfile(p):
            out.add(p)
            continue
        if not os.path.isdir(p):
            raise LintError(f"no such file or directory: {p!r}")
        for root, dirs, files in os.walk(p):
            dirs[:] = [d for d in dirs
                       if not d.startswith(".") and d != "__pycache__"]
            out.update(os.path.join(root, f) for f in files
                       if f.endswith(".py"))
    return sorted(out)


def lint_paths(paths: Sequence[str], rules: Sequence[Rule],
               ) -> Tuple[List[Finding], int]:
    """Run ``rules`` over every ``.py`` file under ``paths``.

    Returns ``(findings, suppressed_count)`` — findings are
    suppression-filtered but *not* baseline-filtered (the caller owns the
    baseline so ``--write-baseline`` can see everything).
    """
    findings: List[Finding] = []
    suppressed = 0
    for fn in collect_files(paths):
        with open(fn, encoding="utf-8") as f:
            ctx = FileContext(fn, f.read())
        for rule in rules:
            for fd in rule.check(ctx):
                if ctx.suppressed(fd.rule, fd.line):
                    suppressed += 1
                else:
                    findings.append(fd)
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings, suppressed


def load_baseline(path: Optional[str]) -> Set[Tuple[str, str, int]]:
    """The committed grandfather list as a set of (rule, path, line) keys.
    A missing/None path is an empty baseline."""
    if not path or not os.path.exists(path):
        return set()
    with open(path, encoding="utf-8") as f:
        entries = json.load(f)
    if not isinstance(entries, list):
        raise LintError(f"baseline {path!r}: expected a JSON list")
    return {(e["rule"], e["path"], int(e["line"])) for e in entries}


def save_baseline(path: str, findings: Sequence[Finding]) -> None:
    """Write ``findings`` as the new baseline (sorted, one entry per
    finding, messages included for reviewability)."""
    with open(path, "w", encoding="utf-8") as f:
        json.dump([fd.to_json() for fd in findings], f, indent=2,
                  sort_keys=True)
        f.write("\n")
