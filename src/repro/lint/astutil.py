"""Small AST helpers shared by the rules (dotted-name resolution, scope
walking, buffer-expression normalisation)."""

from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional

__all__ = ["dotted", "base_name", "normalize", "open_mode_is_binary",
           "keyword_arg", "function_scopes", "local_functions",
           "scope_calls"]


def dotted(node: ast.AST) -> Optional[str]:
    """The dotted name of an expression (``os.replace``,
    ``np.lib.format.open_memmap``, bare ``open``) or None when the chain
    does not bottom out in a plain name."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def base_name(node: ast.AST) -> Optional[str]:
    """The leftmost plain name under an expression — ``buf`` for
    ``buf[a:b].view(...)`` — or None (calls/literals have no stable base)."""
    while isinstance(node, (ast.Attribute, ast.Subscript, ast.Starred)):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def normalize(node: ast.AST) -> str:
    """Structural fingerprint of an expression: two occurrences of the same
    source expression normalise identically (``ast.dump`` without
    positions)."""
    return ast.dump(node, annotate_fields=False)


def keyword_arg(call: ast.Call, name: str) -> Optional[ast.expr]:
    """The value of keyword argument ``name``, or None."""
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def open_mode_is_binary(call: ast.Call) -> bool:
    """True when an ``open()`` call's mode (positional arg 2 or ``mode=``)
    is a string literal containing ``'b'`` — or is not a literal at all,
    which is conservatively treated as possibly-binary."""
    mode: Optional[ast.expr] = None
    if len(call.args) >= 2:
        mode = call.args[1]
    kw = keyword_arg(call, "mode")
    if kw is not None:
        mode = kw
    if mode is None:
        return False
    if isinstance(mode, ast.Constant) and isinstance(mode.value, str):
        return "b" in mode.value
    return True


def function_scopes(tree: ast.Module) -> Iterator[ast.AST]:
    """Every analysis scope in the module: the module itself plus each
    (async) function definition, however nested."""
    yield tree
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def local_functions(tree: ast.Module) -> Dict[str, List[ast.AST]]:
    """Name -> (async) function definitions anywhere in the module, nested
    defs included (lambdas have no name and are excluded)."""
    out: Dict[str, List[ast.AST]] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out.setdefault(node.name, []).append(node)
    return out


def scope_calls(scope: ast.AST) -> Iterator[ast.Call]:
    """Call nodes inside ``scope``, excluding those inside nested function
    definitions (which are their own scopes)."""
    body = scope.body if isinstance(scope, ast.Module) else scope.body
    stack: List[ast.AST] = list(body)
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if isinstance(node, ast.Call):
            yield node
        stack.extend(ast.iter_child_nodes(node))
