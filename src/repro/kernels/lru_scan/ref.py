"""Sequential oracle for the gated linear recurrence."""

import jax
import jax.numpy as jnp


def lru_scan_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """h_t = a_t * h_{t-1} + b_t over axis 1 of [B, S, D]; h_{-1} = 0."""
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    a32 = a.astype(jnp.float32).swapaxes(0, 1)
    b32 = b.astype(jnp.float32).swapaxes(0, 1)
    h0 = jnp.zeros(a.shape[::2], jnp.float32)  # [B, D]
    _, hs = jax.lax.scan(step, h0, (a32, b32))
    return hs.swapaxes(0, 1).astype(a.dtype)
