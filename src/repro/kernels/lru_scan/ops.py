"""Public wrapper for the RG-LRU scan."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .lru_scan import lru_scan_chunked


@functools.partial(jax.jit, static_argnames=("chunk", "interpret", "use_kernel"))
def lru_scan(a: jnp.ndarray, b: jnp.ndarray, *, chunk: int = 256,
             interpret: bool = False, use_kernel: bool = True) -> jnp.ndarray:
    """Gated linear recurrence h_t = a_t⊙h_{t−1} + b_t over [B, S, D]."""
    if not use_kernel:
        from .ref import lru_scan_ref
        return lru_scan_ref(a, b)
    bsz, s, d = a.shape
    c = min(chunk, _next_pow2(s))
    s_pad = -(-s // c) * c
    if s_pad != s:
        pad = [(0, 0), (0, s_pad - s), (0, 0)]
        a = jnp.pad(a, pad, constant_values=1.0)   # identity gate
        b = jnp.pad(b, pad)
    out = lru_scan_chunked(a, b, chunk=c, interpret=interpret)
    return out[:, :s]


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p
