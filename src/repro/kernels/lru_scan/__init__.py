from .ops import lru_scan

__all__ = ["lru_scan"]
