"""RG-LRU gated linear recurrence kernel (RecurrentGemma).

    h_t = a_t ⊙ h_{t−1} + b_t            a, b, h ∈ ℝ^D

Chunked PEMS-style: the sequence streams HBM→VMEM in chunks; the carried
state ``h`` is the resident context (VMEM scratch persisting across the
sequential chunk grid dimension).  Within a chunk the scan runs as a
log₂(C)-step Blelloch doubling on vector registers — no sequential lane
dependence.

Grid: (B, S/C) with the chunk index innermost (TPU grids iterate the last
dimension sequentially, so the scratch carry is well-defined).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _chunk_scan(a, b):
    """Inclusive scan of the affine composition (a, b) along axis 0 via
    doubling: (a1,b1)∘(a2,b2) = (a1·a2, b1·a2 + b2)."""
    c = a.shape[0]
    s = 1
    while s < c:
        a_prev = jnp.concatenate([jnp.ones_like(a[:s]), a[:-s]], axis=0)
        b_prev = jnp.concatenate([jnp.zeros_like(b[:s]), b[:-s]], axis=0)
        mask = (jax.lax.broadcasted_iota(jnp.int32, a.shape, 0) >= s)
        a, b = (
            jnp.where(mask, a_prev * a, a),
            jnp.where(mask, b_prev * a + b, b),
        )
        s *= 2
    return a, b


def _lru_kernel(a_ref, b_ref, o_ref, h_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0].astype(jnp.float32)       # [C, D]
    b = b_ref[0].astype(jnp.float32)
    acc_a, acc_b = _chunk_scan(a, b)
    h0 = h_ref[...]
    h = acc_a * h0[None, :] + acc_b        # [C, D]
    h_ref[...] = h[-1]
    o_ref[0] = h.astype(o_ref.dtype)


def lru_scan_chunked(
    a: jnp.ndarray,             # [B, S, D] gates in (0, 1)
    b: jnp.ndarray,             # [B, S, D] inputs
    *,
    chunk: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    bsz, s, d = a.shape
    assert s % chunk == 0, (s, chunk)
    return pl.pallas_call(
        _lru_kernel,
        grid=(bsz, s // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, d), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, d), a.dtype),
        scratch_shapes=[pltpu.VMEM((d,), jnp.float32)],
        interpret=interpret,
    )(a, b)
