"""In-VMEM bitonic sorting network (the PSRS local-sort hot spot).

One grid step sorts one row of a ``[rows, n]`` batch entirely inside VMEM
(n ≤ 2¹⁶ words fits comfortably).  The compare-exchange stages are expressed
with reshapes and ``jnp.where`` — no gathers — so every stage maps onto TPU
vector lanes; the whole network is log²(n) unrolled vector steps.

This is the thesis' "RAM algorithm inside a swapped-in context": the row is
the context, HBM is the external memory, and the sort never touches HBM until
the row swaps back out.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bitonic_kernel(x_ref, o_ref, *, n: int):
    x = x_ref[0, :]
    log_n = n.bit_length() - 1
    for stage in range(log_n):
        for sub in range(stage, -1, -1):
            stride = 1 << sub
            groups = n // (2 * stride)
            xr = x.reshape(groups, 2, stride)
            a, b = xr[:, 0, :], xr[:, 1, :]
            # Direction: ascending iff bit (stage+1) of the element index is
            # 0; constant within a group, alternating with period
            # 2^(stage-sub) in group index.
            g = jax.lax.broadcasted_iota(jnp.int32, (groups, 1), 0)
            asc = ((g >> (stage - sub)) & 1) == 0
            lo = jnp.minimum(a, b)
            hi = jnp.maximum(a, b)
            na = jnp.where(asc, lo, hi)
            nb = jnp.where(asc, hi, lo)
            x = jnp.stack([na, nb], axis=1).reshape(n)
    o_ref[0, :] = x


def bitonic_sort_rows(x: jnp.ndarray, *, interpret: bool = False) -> jnp.ndarray:
    """Sort each row of ``[rows, n]`` ascending; n must be a power of two."""
    rows, n = x.shape
    assert n & (n - 1) == 0, f"n={n} must be a power of two"
    kernel = functools.partial(_bitonic_kernel, n=n)
    return pl.pallas_call(
        kernel,
        grid=(rows,),
        in_specs=[pl.BlockSpec((1, n), lambda r: (r, 0))],
        out_specs=pl.BlockSpec((1, n), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, n), x.dtype),
        interpret=interpret,
    )(x)
