"""Oracle for the bitonic sort kernel."""

import jax.numpy as jnp


def sort_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Row-wise (or 1-D) ascending sort."""
    return jnp.sort(x, axis=-1)
