"""Public sort wrapper: pads to a power of two with the dtype's max so the
padding sorts to the tail, then slices it off.

Backend selection mirrors the delivery kernel's tri-state ``interpret``
(:func:`repro.kernels.alltoallv_deliver.ops.uses_pallas`): ``None`` (auto,
the default) compiles the Pallas network on TPU and falls back to
``jnp.sort`` on backends without a native Pallas lowering — interpret-mode
execution would serialise the row grid and the log²(n) stages;
``interpret=True`` runs the kernel bit-exactly anywhere (tests);
``use_kernel=False`` forces the ``jnp.sort`` reference.  All paths sort
ascending and are bit-identical on total orders (ints; NaN-free floats).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.alltoallv_deliver.ops import uses_pallas

from .bitonic_sort import bitonic_sort_rows


@functools.partial(jax.jit, static_argnames=("interpret", "use_kernel"))
def sort(x: jnp.ndarray, *, interpret: Optional[bool] = None,
         use_kernel: bool = True) -> jnp.ndarray:
    """Ascending sort of the last axis of a 1-D or 2-D array."""
    if not (use_kernel and uses_pallas(interpret)):
        return jnp.sort(x, axis=-1)

    squeeze = x.ndim == 1
    if squeeze:
        x = x[None, :]
    rows, n = x.shape
    n_pad = _next_pow2(n)
    if n_pad != n:
        fill = _max_of(x.dtype)
        x = jnp.concatenate(
            [x, jnp.full((rows, n_pad - n), fill, x.dtype)], axis=1
        )
    out = bitonic_sort_rows(x, interpret=bool(interpret))[:, :n]
    return out[0] if squeeze else out


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def _max_of(dtype):
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.integer):
        return jnp.iinfo(dtype).max
    return jnp.finfo(dtype).max
