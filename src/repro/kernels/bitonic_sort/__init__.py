from .ops import sort as bitonic_sort

__all__ = ["bitonic_sort"]
