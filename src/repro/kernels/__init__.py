"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel package ships three layers:

* ``<name>.py`` — the ``pl.pallas_call`` kernel with explicit ``BlockSpec``
  HBM→VMEM tiling (the TPU-native form of the thesis' blocked explicit I/O).
* ``ops.py``    — the jit'd public wrapper (padding, reshapes, dtype policy).
* ``ref.py``    — the pure-jnp oracle every test compares against.

Kernels are validated in ``interpret=True`` mode on CPU; on TPU the same
``pallas_call`` compiles to Mosaic.

Kernels:
  flash_attention   — blockwise streaming attention (GQA, causal/full)
  bitonic_sort      — in-VMEM bitonic network (PSRS local-sort hot spot)
  alltoallv_deliver — the thesis' §6.2 direct message delivery as an on-chip
                      permuted block copy with lane-masked boundary handling
  ssd_scan          — Mamba-2 SSD chunked state scan
  lru_scan          — RG-LRU gated linear recurrence (RecurrentGemma)
"""
