"""Blockwise (flash) attention Pallas kernel.

Streaming softmax over KV blocks: Q tiles stay resident in VMEM while K/V
tiles stream HBM→VMEM (the PEMS pattern: the KV sequence is the "external"
data, the running (m, l, acc) statistics are the resident context).  Causal
blocks that are fully masked are skipped with ``pl.when``.

Grid: (BH_q, Sq/bq, Sk/bk), KV innermost so the scratch accumulators carry
across KV steps.  GQA is expressed in the K/V BlockSpec index maps: query
head h of batch b reads KV head ``h // group`` of the same batch.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale, causal, bq, bk, sk_valid, n_kv_blocks):
    i = pl.program_id(1)        # query block
    j = pl.program_id(2)        # kv block

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q0 = i * bq
    k0 = j * bk
    # Skip fully-masked causal blocks (query rows all precede the kv block).
    run = (not causal) or (k0 <= q0 + bq - 1)

    @pl.when(jnp.bool_(run) if isinstance(run, bool) else run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # [bq, d]
        k = k_ref[0].astype(jnp.float32)          # [bk, d]
        v = v_ref[0].astype(jnp.float32)          # [bk, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                  # [bq, bk]

        col = k0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = col < sk_valid
        if causal:
            row = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            mask = mask & (col <= row)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                        # [bq]
        m_cur = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        p = jnp.where(mask, p, 0.0)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_cur

    @pl.when(j == n_kv_blocks - 1)
    def _finish():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


def flash_attention_bh(
    q: jnp.ndarray,            # [BHq, Sq, d]
    k: jnp.ndarray,            # [BHkv, Sk, d]
    v: jnp.ndarray,            # [BHkv, Sk, d]
    *,
    h_q: int,
    h_kv: int,
    causal: bool,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    sk_valid: int | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Attention over flattened (batch·head) leading dims; Sq % block_q == 0
    and Sk % block_k == 0 (ops.py pads).  ``sk_valid`` masks padded KV."""
    bhq, sq, d = q.shape
    _, sk, _ = k.shape
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk)
    group = h_q // h_kv
    scale = (1.0 / math.sqrt(d)) if scale is None else scale
    sk_valid = sk if sk_valid is None else sk_valid
    n_kv = sk // block_k

    def kv_index(h, i, j):
        b = h // h_q
        qh = h % h_q
        return (b * h_kv + qh // group, j, 0)

    kernel = functools.partial(
        _attn_kernel,
        scale=scale, causal=causal, bq=block_q, bk=block_k,
        sk_valid=sk_valid, n_kv_blocks=n_kv,
    )
    return pl.pallas_call(
        kernel,
        grid=(bhq, sq // block_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_k, d), kv_index),
            pl.BlockSpec((1, block_k, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bhq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
