"""Pure-jnp oracle for flash attention (GQA, causal/full, length-masked)."""

from __future__ import annotations

import math

import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray,            # [B, Hq, Sq, d]
    k: jnp.ndarray,            # [B, Hkv, Sk, d]
    v: jnp.ndarray,            # [B, Hkv, Sk, d]
    *,
    causal: bool,
    scale: float | None = None,
    sk_valid: int | None = None,
) -> jnp.ndarray:
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    group = hq // hkv
    scale = (1.0 / math.sqrt(d)) if scale is None else scale
    sk_valid = sk if sk_valid is None else sk_valid

    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32)
    ) * scale
    mask = jnp.arange(sk)[None, :] < sk_valid
    if causal:
        mask = mask & (jnp.arange(sk)[None, :] <= jnp.arange(sq)[:, None])
    s = jnp.where(mask[None, None], s, -1e30)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = jnp.where(mask[None, None], p, 0.0)
    denom = p.sum(axis=-1, keepdims=True)
    p = p / jnp.where(denom == 0.0, 1.0, denom)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32)).astype(q.dtype)
