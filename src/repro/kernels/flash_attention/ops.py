"""Public flash-attention wrapper: padding, GQA flattening, dtype policy."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_bh


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret", "use_kernel"),
)
def flash_attention(
    q: jnp.ndarray,            # [B, Hq, Sq, d]
    k: jnp.ndarray,            # [B, Hkv, Sk, d]
    v: jnp.ndarray,            # [B, Hkv, Sk, d]
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
    use_kernel: bool = True,
) -> jnp.ndarray:
    """Blockwise attention; falls back to the jnp oracle when
    ``use_kernel=False`` (useful on backends without Pallas)."""
    if not use_kernel:
        from .ref import attention_ref
        return attention_ref(q, k, v, causal=causal)

    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    bq = min(block_q, _pad_target(sq, block_q))
    bk = min(block_k, _pad_target(sk, block_k))
    sq_p = _round_up(sq, bq)
    sk_p = _round_up(sk, bk)

    qp = _pad_seq(q, sq_p).reshape(b * hq, sq_p, d)
    kp = _pad_seq(k, sk_p).reshape(b * hkv, sk_p, d)
    vp = _pad_seq(v, sk_p).reshape(b * hkv, sk_p, d)

    out = flash_attention_bh(
        qp, kp, vp,
        h_q=hq, h_kv=hkv, causal=causal,
        block_q=bq, block_k=bk, sk_valid=sk,
        interpret=interpret,
    )
    return out.reshape(b, hq, sq_p, d)[:, :, :sq]


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _pad_target(s: int, block: int) -> int:
    """Smallest usable block size for short sequences (power-of-two ≥ 8)."""
    t = 8
    while t < min(s, block):
        t *= 2
    return t


def _pad_seq(x: jnp.ndarray, s_target: int) -> jnp.ndarray:
    s = x.shape[2]
    if s == s_target:
        return x
    pad = [(0, 0)] * x.ndim
    pad[2] = (0, s_target - s)
    return jnp.pad(x, pad)
