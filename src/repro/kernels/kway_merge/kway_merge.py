"""Tiled k-way merge of pre-partitioned sorted runs (the PSRS merge stage).

Exact splitting (arxiv 0910.2582, §multiway merging) happens in ``ops.py``:
every output tile ``g`` is assigned the per-bucket windows
``[starts[g, j], starts[g+1, j])`` whose union is *exactly* the elements of
global rank ``[g·tile, (g+1)·tile)`` — window lengths sum to ``tile`` across
the buckets, so the windows gather *compactly* into one ``tile``-wide row
per output tile (no per-bucket padding: the gathered traffic is the output
size, not ``v×`` it).  Grid steps therefore merge disjoint output ranges
and never communicate; what is left per tile is ordering its ``tile``
elements.

That ordering is a bitonic sorting network over the row — the same
gather-free ``reshape`` + ``min``/``max``/``where`` idiom as the
``bitonic_sort`` kernel, ``log²(tile)`` unrolled vector steps, one grid
step per tile entirely inside VMEM.  Per output element the work is
``log²(tile)/2`` branchless vector ops — *constant in both n and v* — so
across the grid the merge costs O(n·log² tile), versus the O(n log n)
comparator re-sort of all ``v·cap`` received lanes it replaces (which also
paid to re-discover the order the buckets already had).

``merge_tile_grid`` is the Pallas grid; ``sort_tile_rows`` is the same
network as one batched jnp expression (the CPU/GPU fallback — both produce
the unique ascending permutation of each row, so they are bit-identical
by construction).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def sort_tile_rows(x: jnp.ndarray) -> jnp.ndarray:
    """Ascending bitonic sort of the last axis of ``[..., t]``; ``t`` must
    be a power of two.  Pure jnp — the kernel body runs it on one tile row,
    the CPU/GPU fallback on the whole ``[G, tile]`` batch at once."""
    *lead, t = x.shape
    assert t & (t - 1) == 0, f"tile={t} must be a power of two"
    log_t = t.bit_length() - 1
    for stage in range(log_t):
        for sub in range(stage, -1, -1):
            stride = 1 << sub
            groups = t // (2 * stride)
            xr = x.reshape(*lead, groups, 2, stride)
            a, b = xr[..., 0, :], xr[..., 1, :]
            # Ascending iff bit (stage+1) of the element index is 0 —
            # constant within a group, alternating with period
            # 2^(stage-sub) in group index (bitonic_sort's direction rule).
            g = jax.lax.broadcasted_iota(jnp.int32, (groups, 1), 0)
            asc = ((g >> (stage - sub)) & 1) == 0
            lo = jnp.minimum(a, b)
            hi = jnp.maximum(a, b)
            na = jnp.where(asc, lo, hi)
            nb = jnp.where(asc, hi, lo)
            x = jnp.stack([na, nb], axis=-2).reshape(*lead, t)
    return x


def _kway_merge_kernel(tiles_ref, o_ref):
    o_ref[0, :] = sort_tile_rows(tiles_ref[0, :])


def merge_tile_grid(tiles: jnp.ndarray, *, interpret: bool = False) -> jnp.ndarray:
    """Order each compactly-gathered output tile of ``tiles [G, tile]``;
    one grid step per tile, each entirely in VMEM."""
    G, tile = tiles.shape
    return pl.pallas_call(
        _kway_merge_kernel,
        grid=(G,),
        in_specs=[pl.BlockSpec((1, tile), lambda g: (g, 0))],
        out_specs=pl.BlockSpec((1, tile), lambda g: (g, 0)),
        out_shape=jax.ShapeDtypeStruct((G, tile), tiles.dtype),
        interpret=interpret,
    )(tiles)
