"""Pure-jnp oracle for the tiled k-way merge.

Semantics the kernel must reproduce bit-for-bit: mask every lane at or past
its bucket's count to ``fill``, sort the whole ``v·cap`` population flat, and
keep the lowest ``rcap`` values (``fill``-padded when the population is
smaller than ``rcap``).  This is exactly what PSRS's seed merge stage
computed with ``jnp.sort(recv.reshape(-1))[:rcap]`` on fill-masked buckets.
"""

from __future__ import annotations

import jax.numpy as jnp


def kway_merge_ref(buckets: jnp.ndarray, counts: jnp.ndarray, *,
                   rcap: int, fill) -> jnp.ndarray:
    """Lowest ``rcap`` of the masked ``[v, cap]`` buckets, ascending."""
    buckets = jnp.asarray(buckets)
    v, cap = buckets.shape
    lane = jnp.arange(cap, dtype=jnp.int32)
    masked = jnp.where(lane[None, :] < counts[:, None].astype(jnp.int32),
                       buckets, jnp.asarray(fill, buckets.dtype))
    flat = jnp.sort(masked.reshape(-1))
    if flat.shape[0] >= rcap:
        return flat[:rcap]
    pad = jnp.full((rcap - flat.shape[0],), fill, buckets.dtype)
    return jnp.concatenate([flat, pad])
