"""Public k-way merge wrapper: exact splitting + window gather + dispatch.

``kway_merge(buckets [v, cap], counts [v], rcap=...)`` returns the lowest
``rcap`` elements of the count-masked buckets, ascending, plus the total
received count and an overflow flag — the PSRS merge-stage contract, bit
identical to ``ref.kway_merge_ref`` (and therefore to the seed's dense
``jnp.sort(flat)[:rcap]`` on fill-masked buckets).

Pipeline:

1. **Mask** lanes at/past ``counts[j]`` to ``fill`` — each row is then
   globally ascending (``fill`` is required to be the dtype maximum), and
   the fill lanes become ordinary elements, exactly as the dense re-sort
   treated them.
2. **Exact splitters** (arxiv 0910.2582): for every output tile boundary
   rank ``r = g·tile`` a 32-step MSB-first binary search over the *value
   domain* (order-preserving uint32 bias, so no int64 arithmetic) finds the
   boundary value ``t_r = max u: #{x < u} < r``; duplicates of ``t_r`` are
   then distributed greedily in bucket order, yielding ``starts[g, j]``
   with ``Σ_j (starts[g+1, j] − starts[g, j]) = tile`` exactly.
3. **Compact gather**: tile ``g``'s window lengths sum to exactly ``tile``
   across the buckets, so the windows concatenate (in bucket order, via an
   owner-bucket ``searchsorted`` over the exclusive length prefix) into one
   dense ``tile``-wide row — ``tiles[G, tile]``, each row a permutation of
   its tile's elements.  No per-bucket padding: gather traffic equals
   output size.
4. **Tile merge** — a bitonic sorting network over each row, as the Pallas
   grid (one step per tile) or one batched jnp expression, backend
   dispatched like every other kernel here.

Backend selection follows :func:`repro.kernels.alltoallv_deliver.ops.uses_pallas`:
``interpret=None`` (default) compiles the Pallas kernel on TPU and takes
the batched jnp network on CPU/GPU; ``interpret=True`` runs the
kernel's grid machinery in interpret mode (what the equivalence tests
exercise); ``use_kernel=False`` keeps the dense re-sort reference path.

Deliberately NOT jitted: PSRS's merge stage calls this inside the
executor's own (vmapped) trace, and a nested jit boundary would stop XLA
from fusing the mask/gather into the stage body — same reasoning as the
delivery kernel's ``_dispatch``.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.alltoallv_deliver.ops import uses_pallas

from .kway_merge import merge_tile_grid, sort_tile_rows

_SUPPORTED = ("int32", "uint32")


def _register_barrier_batching() -> None:
    """``lax.optimization_barrier`` has no vmap batching rule in the pinned
    jax; the barrier is shape-preserving and batch-oblivious, so the rule
    is the identity on batch dims.  Registered once, guarded so a future
    jax that ships its own rule wins."""
    try:
        from jax._src.lax.lax import optimization_barrier_p
        from jax.interpreters import batching
        if optimization_barrier_p not in batching.primitive_batchers:
            def _rule(args, dims, **params):
                return optimization_barrier_p.bind(*args, **params), dims
            batching.primitive_batchers[optimization_barrier_p] = _rule
    except ImportError:            # pragma: no cover - jax internals moved
        pass


_register_barrier_batching()


def _materialize(x: jnp.ndarray) -> jnp.ndarray:
    """Fusion barrier: force ``x`` into memory once instead of letting XLA
    re-fuse its producer chain into every consumer (the window gather
    otherwise re-runs inside each tournament stage — measured ~1.5x on the
    whole op on CPU)."""
    try:
        return jax.lax.optimization_barrier(x)
    except NotImplementedError:    # pragma: no cover - missing batching rule
        return x


def _to_biased_u32(x: jnp.ndarray) -> jnp.ndarray:
    """Order-preserving map into uint32 so the value-domain binary search
    needs no 64-bit arithmetic: int32 gets the sign-bit bias, uint32 is
    already in order."""
    if x.dtype == jnp.int32:
        return jax.lax.bitcast_convert_type(x, jnp.uint32) ^ jnp.uint32(
            0x80000000)
    return x


def _exact_starts(rows_u32: jnp.ndarray, ranks: jnp.ndarray) -> jnp.ndarray:
    """Per-bucket window starts for global ``ranks`` over ``v`` ascending
    uint32 rows: ``starts[r, j]`` with ``Σ_j starts[r, j] = ranks[r]``.

    For each rank the MSB-first build finds ``t = max u: #{x < u} < rank``
    (so ``#{x ≤ t} ≥ rank > #{x < t}``); the ``rank − #{x < t}`` duplicates
    of ``t`` are assigned greedily in bucket order, which keeps the starts
    monotone across ranks — consecutive boundaries carve consistent,
    disjoint windows."""
    ranks = ranks.astype(jnp.int32)

    def count_lt(vals):                       # [R] → [R]
        return jax.vmap(
            lambda row: jnp.searchsorted(row, vals, side="left")
        )(rows_u32).sum(axis=0).astype(jnp.int32)

    # lax.fori_loop rather than an unrolled Python loop: the rows become a
    # loop-invariant input materialised once, where the unrolled form let
    # XLA re-fuse the mask/bias producers into every iteration's search
    # (measured ~1.8x on the whole op on CPU), and the trace stays small.
    def bit_step(i, u):
        cand = u | (jnp.uint32(1) << (jnp.uint32(31) - i.astype(jnp.uint32)))
        return jnp.where(count_lt(cand) < ranks, cand, u)

    u = jax.lax.fori_loop(0, 32, bit_step,
                          jnp.zeros(ranks.shape, jnp.uint32))

    lo = jax.vmap(                            # [v, R] elements < t per bucket
        lambda row: jnp.searchsorted(row, u, side="left")
    )(rows_u32).astype(jnp.int32)
    hi = jax.vmap(                            # [v, R] elements <= t
        lambda row: jnp.searchsorted(row, u, side="right")
    )(rows_u32).astype(jnp.int32)
    dups = hi - lo
    need = ranks[None, :] - lo.sum(axis=0, keepdims=True)   # duplicates of t
    cum = jnp.cumsum(dups, axis=0) - dups                   # exclusive prefix
    take = jnp.clip(need - cum, 0, dups)
    return (lo + take).T                                    # [R, v]


def kway_merge(
    buckets: jnp.ndarray,                     # [v, cap]; row j ascending in
                                              # its first counts[j] lanes
    counts: jnp.ndarray,                      # [v] valid lanes per bucket
    *,
    rcap: int,
    tile: int = 256,
    fill,
    interpret: Optional[bool] = None,
    use_kernel: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Merge ``v`` sorted buckets into their lowest ``rcap`` elements.

    Returns ``(merged [rcap], total, overflow)`` where ``total`` is
    ``counts.sum()`` and ``overflow`` flags ``total > rcap`` — the stage
    boundary's truncation signal, computed here so no caller can slice
    first and check later.  ``fill`` must be the dtype maximum (the PSRS
    boundary sentinel): masked lanes must sort to every row's tail.

    Works under ``jax.vmap`` (PSRS calls it per resident context) and in
    any enclosing jit trace.  Only 32-bit integer dtypes are supported —
    the splitter search walks the biased uint32 value domain.
    """
    buckets = jnp.asarray(buckets)
    if buckets.ndim != 2:
        raise ValueError(f"buckets must be [v, cap], got {buckets.shape}")
    v, cap = buckets.shape
    if jnp.dtype(buckets.dtype).name not in _SUPPORTED:
        raise ValueError(
            f"kway_merge supports dtypes {_SUPPORTED}, got "
            f"{jnp.dtype(buckets.dtype).name} (the exact-splitter search "
            "runs in the biased uint32 value domain)"
        )
    if tile < 1 or tile & (tile - 1):
        raise ValueError(f"tile={tile} must be a power of two")
    if rcap < 1:
        raise ValueError(f"rcap={rcap} must be >= 1")
    fmax = int(jnp.iinfo(buckets.dtype).max)
    if isinstance(fill, (int, np.integer)) and int(fill) != fmax:
        raise ValueError(
            f"fill={fill!r} must be the dtype maximum {fmax}: masked lanes "
            "must sort to every bucket's tail for the windows to be "
            "ascending"
        )

    counts = jnp.asarray(counts, jnp.int32)
    total = counts.sum()
    overflow = (total > rcap).astype(jnp.int32)

    fill_v = jnp.asarray(fill, buckets.dtype)
    lane = jnp.arange(cap, dtype=jnp.int32)
    masked = jnp.where(lane[None, :] < counts[:, None], buckets, fill_v)

    n_all = v * cap                           # fill lanes are elements too
    G = -(-rcap // tile)
    ranks = jnp.minimum(
        jnp.arange(G + 1, dtype=jnp.int32) * tile, jnp.int32(n_all))

    rows_u32 = _to_biased_u32(masked)
    starts = _exact_starts(rows_u32, ranks)   # [G+1, v]

    # Compact gather: tile g's per-bucket window lengths sum to exactly
    # `tile` (minus the clamp at n_all on the last tile), so the windows
    # concatenate into one dense [tile] row.  Slot s of tile g belongs to
    # the bucket whose exclusive length-prefix covers s; a searchsorted
    # over that prefix finds it without materialising [G, v, tile].
    lens = starts[1:] - starts[:-1]                            # [G, v]
    cum = jnp.cumsum(lens, axis=1) - lens                      # excl prefix
    slot = jnp.arange(tile, dtype=jnp.int32)
    own = jax.vmap(
        lambda c: jnp.searchsorted(c, slot, side="right")
    )(cum).astype(jnp.int32) - 1                               # [G, tile]
    off = slot[None, :] - jnp.take_along_axis(cum, own, axis=1)
    valid = off < jnp.take_along_axis(lens, own, axis=1)       # last tile only
    pos = jnp.take_along_axis(starts[:-1], own, axis=1) + off
    flat = own * cap + jnp.clip(pos, 0, cap - 1)
    tiles = jnp.where(valid, jnp.take(masked.reshape(-1), flat), fill_v)
    tiles = _materialize(tiles)               # don't re-fuse into the network

    if use_kernel and uses_pallas(interpret):
        merged = merge_tile_grid(tiles, interpret=bool(interpret))
    else:
        merged = sort_tile_rows(tiles)        # batched over the whole grid
    return merged.reshape(G * tile)[:rcap], total, overflow
