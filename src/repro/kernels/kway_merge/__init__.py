from .kway_merge import merge_tile_grid, sort_tile_rows
from .ops import kway_merge
from .ref import kway_merge_ref

__all__ = ["kway_merge", "kway_merge_ref", "merge_tile_grid",
           "sort_tile_rows"]
