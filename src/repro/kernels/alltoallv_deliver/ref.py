"""Oracle for direct delivery: masked transpose."""

import jax.numpy as jnp


def deliver_ref(msgs: jnp.ndarray, counts: jnp.ndarray, *, fill=0) -> jnp.ndarray:
    v, _, omega = msgs.shape
    t = jnp.swapaxes(msgs, 0, 1)                 # [dst, src, ω]
    ct = jnp.swapaxes(counts, 0, 1)              # [dst, src]
    lane = jnp.arange(omega)[None, None, :]
    return jnp.where(lane < ct[..., None], t, fill)
