"""Oracles for direct delivery: masked transpose (+ fused counts)."""

from typing import Optional, Tuple

import jax.numpy as jnp


def deliver_ref(msgs: jnp.ndarray, counts: jnp.ndarray, *, fill=0) -> jnp.ndarray:
    v, _, omega = msgs.shape
    t = jnp.swapaxes(msgs, 0, 1)                 # [dst, src, ω]
    ct = jnp.swapaxes(counts, 0, 1)              # [dst, src]
    lane = jnp.arange(omega)[None, None, :]
    # Cast fill explicitly: a raw uint32 bit pattern > 2**31 would overflow
    # python-int weak typing against an int32/uint32 payload.
    return jnp.where(lane < ct[..., None], t, jnp.asarray(fill, msgs.dtype))


def deliver_fused_ref(
    msgs: jnp.ndarray,
    counts: Optional[jnp.ndarray] = None,
    counts_payload: Optional[jnp.ndarray] = None,
    *,
    fill=None,
) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """Oracle for :func:`..ops.deliver_fused`: plain transpose when ``fill``
    is ``None``, masked transpose otherwise, plus the transposed counts
    payload."""
    if fill is None:
        out = jnp.swapaxes(msgs, 0, 1)
    else:
        out = deliver_ref(msgs, counts, fill=fill)
    ct = None if counts_payload is None else jnp.swapaxes(counts_payload, 0, 1)
    return out, ct


def assemble_proc_ref(
    msgs: jnp.ndarray,                       # [s, P, d, ω]
    counts: Optional[jnp.ndarray] = None,    # [s, P, d]
    counts_payload: Optional[jnp.ndarray] = None,  # [s, P, d]
    *,
    fill=None,
) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """Oracle for :func:`..alltoallv_deliver.assemble_proc_tiles`: stage the
    chunk into destination order — ``out[p, d, j] = msgs[j, p, d]`` — with
    the optional source-side boundary mask and transposed counts payload."""
    out = jnp.moveaxis(msgs, 0, 2)           # [P, d, s, ω]
    if fill is not None:
        cm = jnp.moveaxis(counts, 0, 2)      # [P, d, s]
        lane = jnp.arange(msgs.shape[-1])[None, None, None, :]
        out = jnp.where(lane < cm[..., None], out,
                        jnp.asarray(fill, msgs.dtype))
    ct = None
    if counts_payload is not None:
        ct = jnp.moveaxis(counts_payload, 0, 2)
    return out, ct
