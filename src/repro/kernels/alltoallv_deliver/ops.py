"""Public wrappers for the direct-delivery kernel.

Backend selection (``interpret`` tri-state) makes the kernel path the
default rather than an opt-in:

* ``interpret=None`` (auto, the default) — compiled Pallas on TPU; on
  backends without a native Pallas lowering (CPU, and GPU in this repo's
  toolchain) the vectorised reference path is used instead, because
  interpret-mode execution serialises the (v, v, ω/ωt) grid and is far
  slower than one fused XLA transpose.
* ``interpret=True``  — Pallas interpret mode: bit-exact emulation of the
  kernel's grid/index-map machinery on any backend (what the equivalence
  tests run).
* ``interpret=False`` — force the compiled Pallas kernel.

``use_kernel=False`` bypasses the kernel entirely (pure-jnp reference),
which is what the seed implementation did; it is kept so equivalence can be
asserted end-to-end (``psrs_sort(..., use_kernel=...)``).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .alltoallv_deliver import assemble_proc_tiles, deliver_tiles


def check_fill_range(fill, dtype) -> None:
    """Reject a ``fill`` value the payload dtype cannot represent.

    The kernels bake ``fill`` into the trace with ``jnp.asarray(fill,
    msgs.dtype)``, which wraps silently for out-of-range integers — a
    ``fill=INT_MAX`` boundary sentinel on an ``int8``/``uint16`` payload
    would arrive as ``-1``/``65535`` and corrupt every masked lane.  Checked
    here, once, for every delivery path (kernel, vectorised fallback, and
    the collective layer's word-level fill patterns)."""
    dt = jnp.dtype(dtype)
    if not isinstance(fill, (int, float, np.integer, np.floating)):
        return                                 # traced/abstract: can't check
    if jnp.issubdtype(dt, jnp.integer):
        info = jnp.iinfo(dt)
        if isinstance(fill, (float, np.floating)) and not float(fill).is_integer():
            raise ValueError(
                f"fill={fill!r} is not representable in integer payload "
                f"dtype {dt.name}"
            )
        if not info.min <= int(fill) <= info.max:
            raise ValueError(
                f"fill={fill!r} out of range for payload dtype {dt.name} "
                f"[{info.min}, {info.max}]: the cast would wrap silently"
            )
    elif jnp.issubdtype(dt, jnp.floating):
        try:
            f = float(fill)
        except OverflowError:
            # An integer too large even for float64 certainly overflows the
            # payload dtype; keep the advertised exception type.
            raise ValueError(
                f"fill={fill!r} overflows payload dtype {dt.name}"
            ) from None
        if math.isfinite(f) and abs(f) > float(jnp.finfo(dt).max):
            raise ValueError(
                f"fill={fill!r} overflows payload dtype {dt.name} "
                f"(max {float(jnp.finfo(dt).max):g}): the cast would "
                "produce inf"
            )


def uses_pallas(interpret: Optional[bool] = None) -> bool:
    """Whether delivery would emit a ``pallas_call`` for this ``interpret``
    setting on the current backend.  The single source of truth for the
    backend dispatch — the collective layer consults it too, so its
    CPU-fallback heuristics can never desync from the kernel dispatch."""
    if interpret is None:
        return jax.default_backend() == "tpu"
    return True


def _dispatch(msgs, counts, counts_payload, *, fill, interpret, use_kernel):
    # Deliberately NOT jitted: the collective layer calls this inside its own
    # trace, and a nested jit boundary stops XLA from fusing the delivery
    # transpose into the store-row rebuild (~1.4× regression at small ω).
    # Direct (eager) calls from tests trace per-op, which is fine there.
    if use_kernel and uses_pallas(interpret):
        return deliver_tiles(
            msgs, counts, counts_payload, fill=fill,
            interpret=bool(interpret),
        )
    # Vectorised reference path: one fused transpose(+mask), the CPU/GPU
    # fallback.  Semantically identical to the kernel.
    from .ref import deliver_fused_ref
    return deliver_fused_ref(msgs, counts, counts_payload, fill=fill)


def deliver(msgs: jnp.ndarray, counts: jnp.ndarray, *, fill=0,
            interpret: Optional[bool] = None,
            use_kernel: bool = True) -> jnp.ndarray:
    """PEMS2 direct delivery of ``msgs [v, v, ω]`` with valid lengths
    ``counts [v, v]`` → ``[v(dst), v(src), ω]``, lanes past the count set to
    ``fill``."""
    check_fill_range(fill, msgs.dtype)
    out, _ = _dispatch(
        msgs, counts.astype(jnp.int32), None, fill=fill, interpret=interpret,
        use_kernel=use_kernel,
    )
    return out


def deliver_fused(
    msgs: jnp.ndarray,                        # [v, v, ω] payload (any 4-byte dtype)
    counts: Optional[jnp.ndarray] = None,     # [v, v] int32 mask lengths
    counts_payload: Optional[jnp.ndarray] = None,  # [v, v] raw counts words
    *,
    fill=None,
    interpret: Optional[bool] = None,
    use_kernel: bool = True,
) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """Delivery with the optional fusions the collective layer uses: the
    boundary mask only when ``fill`` is given, and the counts transpose as a
    second output of the same kernel call.  Returns ``(out, ct)``."""
    if fill is not None and counts is None:
        raise ValueError("fill requires counts")
    if fill is not None:
        check_fill_range(fill, msgs.dtype)
    return _dispatch(
        msgs,
        None if fill is None else counts.astype(jnp.int32),
        counts_payload,
        fill=fill, interpret=interpret, use_kernel=use_kernel,
    )


def assemble_proc_fused(
    msgs: jnp.ndarray,                        # [s, P, d, ω] pre-all_to_all chunk
    counts: Optional[jnp.ndarray] = None,     # [s, P, d] int32 mask lengths
    counts_payload: Optional[jnp.ndarray] = None,  # [s, P, d] raw counts words
    *,
    fill=None,
    interpret: Optional[bool] = None,
    use_kernel: bool = True,
) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """Mesh-path staging with the same fusions as :func:`deliver_fused`,
    over the ``(src_proc, dst_proc)``-tiled grid: the α-chunk ``[s, P, d,
    ω]`` is assembled into destination order as ``out[p, d, j] = msgs[j, p,
    d]`` (boundary mask applied at the source; transposed counts payload as
    the fused second output) so the subsequent ``all_to_all`` lands every
    piece directly in its destination rows.  Same backend dispatch as the
    ``P == 1`` route: compiled Pallas on TPU, the vectorised reference on
    CPU/GPU, interpret mode for tests."""
    if fill is not None and counts is None:
        raise ValueError("fill requires counts")
    if fill is not None:
        check_fill_range(fill, msgs.dtype)
    if use_kernel and uses_pallas(interpret):
        return assemble_proc_tiles(
            msgs,
            None if fill is None else counts.astype(jnp.int32),
            counts_payload, fill=fill, interpret=bool(interpret),
        )
    from .ref import assemble_proc_ref
    return assemble_proc_ref(
        msgs,
        None if fill is None else counts.astype(jnp.int32),
        counts_payload, fill=fill,
    )
