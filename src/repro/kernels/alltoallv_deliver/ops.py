"""Public wrapper for the direct-delivery kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .alltoallv_deliver import deliver_tiles


@functools.partial(jax.jit, static_argnames=("interpret", "use_kernel", "fill"))
def deliver(msgs: jnp.ndarray, counts: jnp.ndarray, *, fill=0,
            interpret: bool = False, use_kernel: bool = True) -> jnp.ndarray:
    """PEMS2 direct delivery of ``msgs [v, v, ω]`` with valid lengths
    ``counts [v, v]`` → ``[v(dst), v(src), ω]``."""
    if not use_kernel:
        from .ref import deliver_ref
        return deliver_ref(msgs, counts, fill=fill)
    return deliver_tiles(msgs, counts.astype(jnp.int32), fill=fill,
                         interpret=interpret)
