from .alltoallv_deliver import deliver_tiles
from .ops import deliver, deliver_fused, uses_pallas

__all__ = ["deliver", "deliver_fused", "deliver_tiles", "uses_pallas"]
