from .ops import deliver

__all__ = ["deliver"]
