from .alltoallv_deliver import assemble_proc_tiles, deliver_tiles
from .ops import (
    assemble_proc_fused,
    check_fill_range,
    deliver,
    deliver_fused,
    uses_pallas,
)

__all__ = [
    "assemble_proc_fused",
    "assemble_proc_tiles",
    "check_fill_range",
    "deliver",
    "deliver_fused",
    "deliver_tiles",
    "uses_pallas",
]
