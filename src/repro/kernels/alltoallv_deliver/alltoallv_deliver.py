"""Direct message delivery (thesis §6.2) as a Pallas kernel.

The PEMS2 insight: deliver each message's aligned body straight into the
destination context and fix up the unaligned edges from a small cache.  On
TPU the analogue of the disk block is the 128-lane tile: the kernel streams
message tiles HBM→VMEM with a *permuted* ``BlockSpec`` index map (the
source's (s, d) tile lands at the destination's (d, s) slot — the offset
table ``T`` baked into the index map), and the per-message valid length
``counts[s, d]`` is applied as a lane mask — the boundary-block fix-up,
performed while the tile is resident instead of with a read-modify-write
cycle.

Grid: ``(dst, src, ω/ωt)`` — one grid step moves one 128-lane ω-tile of one
message, so arbitrarily large messages stream through VMEM in block-sized
pieces instead of requiring the full ω payload resident at once.  For the
``P > 1`` mesh path the grid grows a real-processor axis
(:func:`assemble_proc_tiles`): each α-chunk is staged into the
communication buffer with a ``(dst_proc, dst_local, src_local, ω/ωt)`` grid
whose output index map writes source j's tile at the slot ``all_to_all``
ships straight to the destination process' context row — the same
offset-table permutation, now spanning the ``(src_proc, dst_proc)`` tiling
of Alg 7.1.3, applied at the sender so the received buffer lands in the
destination rows verbatim.  Two optional fusions ride along (both
variants):

* ``fill`` — the boundary mask.  When given, lanes past ``counts[s, d]`` are
  overwritten with ``fill`` while the tile is in VMEM (the receiver then
  never needs its own mask pass).  When ``None`` the tile is copied verbatim
  and the counts input is not even streamed.
* ``counts_payload`` — the counts matrix itself.  Alltoallv must also hand
  every receiver the transposed counts; passing the raw counts words here
  adds a second (1, 1)-block output ``ct[d, s] = counts_payload[s, d]`` to
  the same ``pallas_call``, so the counts transpose costs no extra kernel
  launch or HBM round-trip.

Backend selection — compiled Pallas on TPU, the vectorised fallback on
CPU/GPU, interpret mode for bit-exact kernel emulation in tests — lives in
:mod:`.ops` (``deliver`` / ``deliver_fused``); this module is the kernel
itself and always emits a ``pallas_call``.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE_TILE = 128  # TPU lane width: the on-chip analogue of the disk block


def _deliver_kernel(*refs, omega_tile: int, fill, masked: bool,
                    with_counts: bool):
    """One grid step: move one ω-tile of message (s → d), boundary-masked."""
    refs = list(refs)
    cnt_ref = refs.pop(0) if masked else None
    cp_ref = refs.pop(0) if with_counts else None
    msg_ref = refs.pop(0)
    out_ref = refs.pop(0)
    ct_ref = refs.pop(0) if with_counts else None

    data = msg_ref[0, 0, :]
    if masked:
        t = pl.program_id(2)
        cnt = cnt_ref[0, 0]
        lane = t * omega_tile + jax.lax.broadcasted_iota(
            jnp.int32, (omega_tile,), 0
        )
        data = jnp.where(lane < cnt, data, jnp.asarray(fill, data.dtype))
    out_ref[0, 0, :] = data
    if with_counts:
        # Idempotent across the ω-tile axis: the (d, s) block is revisited by
        # every t step with the same value, staying resident in VMEM.
        ct_ref[0, 0] = cp_ref[0, 0]


def deliver_tiles(
    msgs: jnp.ndarray,                       # [v, v, ω]  (src, dst, payload)
    counts: Optional[jnp.ndarray] = None,    # [v, v] int32 valid lengths
    counts_payload: Optional[jnp.ndarray] = None,  # [v, v] raw counts words
    *,
    fill=None,
    omega_tile: int = LANE_TILE,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """Returns ``(out, ct)`` with ``out[d, s] = msgs[s, d]`` (lanes ≥
    ``counts[s, d]`` replaced by ``fill`` when ``fill`` is not ``None``) and
    ``ct[d, s] = counts_payload[s, d]`` (``None`` when no payload given)."""
    v, v2, omega = msgs.shape
    assert v == v2, msgs.shape
    masked = fill is not None
    if masked and counts is None:
        raise ValueError("fill requires counts")
    with_counts = counts_payload is not None

    wt = min(omega_tile, omega)
    nt = -(-omega // wt)                     # ceil: last tile may be ragged
    kernel = functools.partial(
        _deliver_kernel, omega_tile=wt, fill=fill, masked=masked,
        with_counts=with_counts,
    )

    in_specs, args = [], []
    if masked:
        in_specs.append(pl.BlockSpec((1, 1), lambda d, s, t: (s, d)))
        args.append(counts)
    if with_counts:
        in_specs.append(pl.BlockSpec((1, 1), lambda d, s, t: (s, d)))
        args.append(counts_payload)
    in_specs.append(pl.BlockSpec((1, 1, wt), lambda d, s, t: (s, d, t)))
    args.append(msgs)

    out_specs = [pl.BlockSpec((1, 1, wt), lambda d, s, t: (d, s, t))]
    out_shape = [jax.ShapeDtypeStruct((v, v, omega), msgs.dtype)]
    if with_counts:
        out_specs.append(pl.BlockSpec((1, 1), lambda d, s, t: (d, s)))
        out_shape.append(
            jax.ShapeDtypeStruct((v, v), counts_payload.dtype)
        )

    out = pl.pallas_call(
        kernel,
        grid=(v, v, nt),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*args)
    if with_counts:
        return out[0], out[1]
    return out[0], None



def _assemble_proc_kernel(*refs, omega_tile: int, fill, masked: bool,
                          with_counts: bool):
    """One grid step of the mesh variant: stage one ω-tile of the message
    (src_local j → dst_proc p, dst_local d) into the communication buffer,
    boundary-masked at the source."""
    refs = list(refs)
    cnt_ref = refs.pop(0) if masked else None
    cp_ref = refs.pop(0) if with_counts else None
    msg_ref = refs.pop(0)
    out_ref = refs.pop(0)
    ct_ref = refs.pop(0) if with_counts else None

    data = msg_ref[0, 0, 0, :]
    if masked:
        t = pl.program_id(3)
        cnt = cnt_ref[0, 0, 0]
        lane = t * omega_tile + jax.lax.broadcasted_iota(
            jnp.int32, (omega_tile,), 0
        )
        data = jnp.where(lane < cnt, data, jnp.asarray(fill, data.dtype))
    out_ref[0, 0, 0, :] = data
    if with_counts:
        # Revisited with the same value by every ω-tile step (idempotent).
        ct_ref[0, 0, 0] = cp_ref[0, 0, 0]


def assemble_proc_tiles(
    msgs: jnp.ndarray,                       # [s, P, d, ω]  (src_local, dst_proc, dst_local, payload)
    counts: Optional[jnp.ndarray] = None,    # [s, P, d] int32 valid lengths
    counts_payload: Optional[jnp.ndarray] = None,  # [s, P, d] raw counts words
    *,
    fill=None,
    omega_tile: int = LANE_TILE,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """The ``(src_proc, dst_proc)``-tiled grid of the ``P > 1`` mesh path:
    assemble one real processor's α-chunk into the communication buffer in
    destination order, so the subsequent ``all_to_all`` lands each piece
    directly in its destination rows (the sender-side message staging of
    Alg 7.1.3 — the mesh analogue of writing each message straight into the
    destination context).

    ``msgs`` holds the chunk's source-context rows: axis 0 the local source
    contexts, axis 1 the destination real processor, axis 2 its destination
    contexts covered by the chunk.  Returns ``(out, ct)`` with
    ``out[p, d, j] = msgs[j, p, d]`` (lanes ≥ ``counts[j, p, d]`` replaced
    by ``fill`` when given — the boundary fix-up applied while the tile is
    staged) and ``ct[p, d, j] = counts_payload[j, p, d]`` (``None`` when no
    payload given): the transposed counts ride along to the same receiver.
    """
    s, Pn, d, omega = msgs.shape
    masked = fill is not None
    if masked and counts is None:
        raise ValueError("fill requires counts")
    with_counts = counts_payload is not None

    wt = min(omega_tile, omega)
    nt = -(-omega // wt)                     # ceil: last tile may be ragged
    kernel = functools.partial(
        _assemble_proc_kernel, omega_tile=wt, fill=fill, masked=masked,
        with_counts=with_counts,
    )

    in_specs, args = [], []
    if masked:
        in_specs.append(pl.BlockSpec((1, 1, 1), lambda p, d, j, t: (j, p, d)))
        args.append(counts)
    if with_counts:
        in_specs.append(pl.BlockSpec((1, 1, 1), lambda p, d, j, t: (j, p, d)))
        args.append(counts_payload)
    in_specs.append(
        pl.BlockSpec((1, 1, 1, wt), lambda p, d, j, t: (j, p, d, t))
    )
    args.append(msgs)

    # The (p, d) output tiling is the offset table T spanning the process
    # grid: source j's tile for destination (p, d) lands at the slot the
    # all_to_all ships straight to process p's context row d.
    out_specs = [
        pl.BlockSpec((1, 1, 1, wt), lambda p, d, j, t: (p, d, j, t))
    ]
    out_shape = [jax.ShapeDtypeStruct((Pn, d, s, omega), msgs.dtype)]
    if with_counts:
        out_specs.append(
            pl.BlockSpec((1, 1, 1), lambda p, d, j, t: (p, d, j))
        )
        out_shape.append(
            jax.ShapeDtypeStruct((Pn, d, s), counts_payload.dtype)
        )

    out = pl.pallas_call(
        kernel,
        grid=(Pn, d, s, nt),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*args)
    if with_counts:
        return out[0], out[1]
    return out[0], None
