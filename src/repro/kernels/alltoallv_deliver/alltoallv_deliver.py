"""Direct message delivery (thesis §6.2) as a Pallas kernel.

The PEMS2 insight: deliver each message's aligned body straight into the
destination context and fix up the unaligned edges from a small cache.  On
TPU the analogue of the disk block is the 128-lane tile: the kernel streams
message tiles HBM→VMEM with a *permuted* ``BlockSpec`` index map (the
source's (s, d) tile lands at the destination's (d, s) slot — the offset
table ``T`` baked into the index map), and the per-message valid length
``counts[s, d]`` is applied as a lane mask — the boundary-block fix-up,
performed while the tile is resident instead of with a read-modify-write
cycle.

Grid: (dst, src).  One grid step moves one message.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _deliver_kernel(cnt_ref, msg_ref, out_ref, *, omega: int, fill):
    cnt = cnt_ref[0, 0]
    data = msg_ref[0, 0, :]
    lane = jax.lax.broadcasted_iota(jnp.int32, (omega,), 0)
    out_ref[0, 0, :] = jnp.where(lane < cnt, data, fill)


def deliver_tiles(
    msgs: jnp.ndarray,          # [v, v, ω]  (src, dst, payload)
    counts: jnp.ndarray,        # [v, v] int32 valid lengths
    *,
    fill=0,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns ``out [v, v, ω]`` with ``out[d, s, :counts[s, d]] ==
    msgs[s, d, :counts[s, d]]`` and ``fill`` elsewhere."""
    v, v2, omega = msgs.shape
    assert v == v2, msgs.shape
    kernel = functools.partial(_deliver_kernel, omega=omega, fill=fill)
    return pl.pallas_call(
        kernel,
        grid=(v, v),
        in_specs=[
            pl.BlockSpec((1, 1), lambda d, s: (s, d)),
            pl.BlockSpec((1, 1, omega), lambda d, s: (s, d, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, omega), lambda d, s: (d, s, 0)),
        out_shape=jax.ShapeDtypeStruct((v, v, omega), msgs.dtype),
        interpret=interpret,
    )(counts, msgs)
