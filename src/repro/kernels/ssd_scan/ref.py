"""Sequential oracle for the SSD recurrence."""

import jax
import jax.numpy as jnp


def ssd_scan_ref(x, dt, A, Bm, Cm):
    """x [B,H,S,P], dt [B,H,S], A [H], Bm/Cm [B,S,N] → y [B,H,S,P]."""
    bsz, h, s, p = x.shape
    n = Bm.shape[-1]

    x32 = x.astype(jnp.float32)
    dt32 = dt.astype(jnp.float32)
    A32 = A.astype(jnp.float32)
    B32 = Bm.astype(jnp.float32)
    C32 = Cm.astype(jnp.float32)

    def step(S, t):
        xt = x32[:, :, t]                       # [B, H, P]
        dtt = dt32[:, :, t]                     # [B, H]
        bt = B32[:, t]                          # [B, N]
        ct = C32[:, t]                          # [B, N]
        decay = jnp.exp(A32[None, :] * dtt)     # [B, H]
        S = decay[..., None, None] * S + (
            dtt[..., None, None]
            * bt[:, None, :, None]
            * xt[:, :, None, :]
        )                                        # [B, H, N, P]
        yt = jnp.einsum("bn,bhnp->bhp", ct, S)
        return S, yt

    S0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    _, ys = jax.lax.scan(step, S0, jnp.arange(s))
    return jnp.moveaxis(ys, 0, 2).astype(x.dtype)  # [B, H, S, P]
