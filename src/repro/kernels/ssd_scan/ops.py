"""Public wrapper for the SSD scan."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .ssd_scan import ssd_scan_chunked


@functools.partial(jax.jit, static_argnames=("chunk", "interpret", "use_kernel"))
def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 128, interpret: bool = False,
             use_kernel: bool = True):
    """Mamba-2 SSD scan; pads the sequence to a chunk multiple (padded steps
    use dt=0, which is the identity transition)."""
    if not use_kernel:
        from .ref import ssd_scan_ref
        return ssd_scan_ref(x, dt, A, Bm, Cm)
    bsz, h, s, p = x.shape
    c = min(chunk, _next_pow2(s))
    s_pad = -(-s // c) * c
    if s_pad != s:
        d = s_pad - s
        x = jnp.pad(x, [(0, 0), (0, 0), (0, d), (0, 0)])
        dt = jnp.pad(dt, [(0, 0), (0, 0), (0, d)])
        Bm = jnp.pad(Bm, [(0, 0), (0, d), (0, 0)])
        Cm = jnp.pad(Cm, [(0, 0), (0, d), (0, 0)])
    out = ssd_scan_chunked(x, dt, A, Bm, Cm, chunk=c, interpret=interpret)
    return out[:, :, :s]


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p
