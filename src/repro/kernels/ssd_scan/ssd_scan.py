"""Mamba-2 SSD (state-space duality) chunked scan kernel.

Per head, state S ∈ ℝ^{P×N}:

    S_t = exp(A·dt_t)·S_{t−1} + dt_t·x_t ⊗ B_t
    y_t = S_t·C_t  (+ D·x_t applied in ops.py)

The SSD chunk decomposition (Dao & Gu 2024) splits the sequence into chunks
of length C: the *intra-chunk* term is a masked quadratic form
(C·Bᵀ ⊙ decay) @ x — MXU matmuls — and the *inter-chunk* term propagates the
carried state.  That carried state is the PEMS context: it stays resident in
VMEM scratch while sequence chunks stream HBM→VMEM, one grid step per chunk.

Grid: (B, H, S/C), chunk innermost (sequential on TPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, s_ref, *,
                chunk: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    x = x_ref[0, 0].astype(jnp.float32)        # [C, P]
    dt = dt_ref[0, 0].astype(jnp.float32)      # [C]
    A = a_ref[0].astype(jnp.float32)           # scalar (per head), A < 0
    Bm = b_ref[0].astype(jnp.float32)          # [C, N]
    Cm = c_ref[0].astype(jnp.float32)          # [C, N]

    cdt = jnp.cumsum(dt)                       # [C] cumulative Δt
    # Intra-chunk quadratic form: W_ti = (C_t·B_i) · exp(A(cdt_t−cdt_i)) · dt_i,
    # lower-triangular.
    G = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                           # [C, C]
    seg = A * (cdt[:, None] - cdt[None, :])     # [C, C]
    row = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = row >= col
    M = jnp.where(causal, jnp.exp(jnp.where(causal, seg, 0.0)), 0.0)
    W = G * M * dt[None, :]
    y_intra = jax.lax.dot_general(
        W, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                           # [C, P]

    # Inter-chunk: y_t += exp(A·cdt_t) · (C_t · S_carry)
    S0 = s_ref[...]                             # [N, P]
    decay_t = jnp.exp(A * cdt)                  # [C]
    y_carry = decay_t[:, None] * jax.lax.dot_general(
        Cm, S0, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                           # [C, P]

    y_ref[0, 0] = (y_intra + y_carry).astype(y_ref.dtype)

    # State update: S' = exp(A·cdt_C)·S + Σ_i exp(A(cdt_C−cdt_i))·dt_i·B_i⊗x_i
    wt = jnp.exp(A * (cdt[-1] - cdt)) * dt      # [C]
    S_new = jnp.exp(A * cdt[-1]) * S0 + jax.lax.dot_general(
        Bm * wt[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                           # [N, P]
    s_ref[...] = S_new


def ssd_scan_chunked(
    x: jnp.ndarray,             # [B, H, S, P]
    dt: jnp.ndarray,            # [B, H, S]   (post-softplus, > 0)
    A: jnp.ndarray,             # [H]         (negative)
    Bm: jnp.ndarray,            # [B, S, N]   (ngroups = 1, shared over heads)
    Cm: jnp.ndarray,            # [B, S, N]
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    bsz, h, s, p = x.shape
    n = Bm.shape[-1]
    assert s % chunk == 0, (s, chunk)
    return pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(bsz, h, s // chunk),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda b, hh, j: (b, hh, j, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, hh, j: (b, hh, j)),
            pl.BlockSpec((1,), lambda b, hh, j: (hh,)),
            pl.BlockSpec((1, chunk, n), lambda b, hh, j: (b, j, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, hh, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, p), lambda b, hh, j: (b, hh, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, h, s, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
