from .adamw import adamw_init, adamw_update, OptConfig
from .schedule import cosine_schedule

__all__ = ["OptConfig", "adamw_init", "adamw_update", "cosine_schedule"]
