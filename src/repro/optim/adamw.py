"""AdamW with optional int8-quantized moments.

The int8 variant is the distributed-optimization trick that makes the
trillion-parameter MoE configs trainable at all (DESIGN.md §6): m and v are
stored as int8 with a per-tensor f32 scale (blockwise absmax), cutting
optimizer-state HBM 4× and, with the PEMS host-offload driver, the stream
volume 4×.  Dequant→update→requant happens inside the jitted step.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    quantize_moments: bool = False   # int8 m/v (for the giant MoE configs)
    block: int = 2048                # quantization block size
    # Scan the update over the leading (layer-stack) dim of big tensors so
    # f32 dequant/update transients exist for one layer at a time — PEMS
    # context swapping applied to the optimizer (§Perf iteration #5).
    scan_stacked: bool = False
    scan_min_dim: int = 8            # only scan leaves with shape[0] >= this


def adamw_init(params, cfg: OptConfig) -> Dict:
    def moment(p):
        if cfg.quantize_moments:
            # Shape-preserving int8 blocks along the last dim: q inherits the
            # parameter's sharding exactly (no resharding in the update).
            last = p.shape[-1] if p.ndim else 1
            nb = -(-last // cfg.block)
            return {
                "q": jnp.zeros(p.shape, jnp.int8),
                "scale": jnp.zeros(p.shape[:-1] + (nb,), jnp.float32),
            }
        return jnp.zeros_like(p, jnp.float32)

    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(moment, params),
        "v": jax.tree.map(moment, params),
    }


def _blocked(x: jnp.ndarray, block: int):
    last = x.shape[-1]
    nb = -(-last // block)
    pad = nb * block - last
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x.reshape(x.shape[:-1] + (nb, block)), last


def _dequant(q: Dict, shape, block: int) -> jnp.ndarray:
    qb, last = _blocked(q["q"].astype(jnp.float32), block)
    x = qb * q["scale"][..., None] / 127.0
    x = x.reshape(x.shape[:-2] + (-1,))[..., :last]
    return x.reshape(shape)


def _quant(x: jnp.ndarray, block: int) -> Dict:
    xb, last = _blocked(x, block)
    scale = jnp.max(jnp.abs(xb), axis=-1)
    safe = jnp.where(scale == 0.0, 1.0, scale)
    qb = jnp.clip(jnp.round(xb / safe[..., None] * 127.0), -127, 127)
    q = qb.reshape(qb.shape[:-2] + (-1,))[..., :last].reshape(x.shape)
    return {"q": q.astype(jnp.int8), "scale": scale}


def adamw_update(params, grads, state: Dict, cfg: OptConfig,
                 lr_scale=1.0) -> Tuple[Dict, Dict, Dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = cfg.lr * lr_scale

    # Global-norm gradient clip.
    gsq = sum(jnp.sum(g.astype(jnp.float32) ** 2)
              for g in jax.tree.leaves(grads))
    gnorm = jnp.sqrt(gsq)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        if cfg.quantize_moments:
            m_f = _dequant(m, p.shape, cfg.block)
            v_f = _dequant(v, p.shape, cfg.block)
        else:
            m_f, v_f = m, v
        m_f = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_f = cfg.b2 * v_f + (1 - cfg.b2) * g * g
        mh = m_f / bc1
        vh = v_f / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + (
            cfg.weight_decay * p.astype(jnp.float32))
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        if cfg.quantize_moments:
            return p_new, _quant(m_f, cfg.block), _quant(v_f, cfg.block)
        return p_new, m_f, v_f

    def upd_leaf(p, g, m, v):
        if (cfg.scan_stacked and p.ndim >= 3
                and p.shape[0] >= cfg.scan_min_dim):
            def body(_, slc):
                return None, upd(*slc)
            _, (p2, m2, v2) = jax.lax.scan(body, None, (p, g, m, v))
            return p2, m2, v2
        return upd(p, g, m, v)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd_leaf(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"step": step, "m": new_m, "v": new_v}, {
        "gnorm": gnorm, "lr": lr,
    }
