"""CGM inclusive prefix sum on PEMS (thesis §8.4.2).

Three virtual supersteps: local total → Gather at root → root prefix-sums the
v totals → Bcast offsets → local cumsum + offset.  Communication volume is
O(v) independent of n, which is why this application benefits most from the
``sliced`` driver (the data field is only touched in the first and last
superstep — cf. Fig 8.14's flat mmap curves)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ContextLayout, Pems, PemsConfig


def _build(v: int, k: int, n_v: int, driver: str, tier: str = "device",
           backing_path=None, device_cap_bytes=None,
           io_driver=None, io_queue_depth=None):
    lo = (
        ContextLayout()
        .add("x", (n_v,), jnp.int32)
        .add("tot", (1,), jnp.int32)
        .add("atot", (v, 1), jnp.int32)
        .add("offs", (v,), jnp.int32)
        .add("res", (n_v,), jnp.int32)
    )
    io_kw = {}
    if io_driver is not None:
        io_kw["io_driver"] = io_driver
    if io_queue_depth is not None:
        io_kw["io_queue_depth"] = io_queue_depth
    pems = Pems(PemsConfig(v=v, k=k, driver=driver, tier=tier,
                           backing_path=backing_path,
                           device_cap_bytes=device_cap_bytes, **io_kw), lo)

    def local_total(rho, ctx):
        return ctx.set("tot", ctx.get("x").sum()[None])

    def root_prefix(rho, ctx):
        tots = ctx.get("atot")[:, 0]
        offs = jnp.cumsum(tots) - tots          # exclusive prefix of totals
        return ctx.set("offs", offs)

    def local_prefix(rho, ctx):
        x = ctx.get("x")
        off = ctx.get("offs")[rho]
        return ctx.set("res", jnp.cumsum(x) + off)

    def program(blocks):
        store = pems.init().with_field("x", blocks)
        store = pems.superstep(store, local_total,
                               reads=["x"], writes=["tot"])
        store = pems.gather(store, "tot", "atot", root=0)
        store = pems.superstep(store, root_prefix,
                               reads=["atot"], writes=["offs"])
        store = pems.bcast(store, "offs", root=0)
        store = pems.superstep(store, local_prefix,
                               reads=["x", "offs"], writes=["res"])
        return store.field("res")

    if tier == "device":
        program = jax.jit(program)
    return pems, program


def prefix_sum(x, v: int, k: int = 1, driver: str = "explicit",
               return_pems: bool = False, tier: str = "device",
               backing_path=None, device_cap_bytes=None,
               io_driver=None, io_queue_depth=None):
    """Inclusive prefix sum of int32 ``x`` ([n], n divisible by v) on PEMS."""
    x = jnp.asarray(x, jnp.int32)
    n = x.shape[0]
    if n % v:
        raise ValueError(f"n={n} must be divisible by v={v}")
    pems, program = _build(v, k, n // v, driver, tier=tier,
                           backing_path=backing_path,
                           device_cap_bytes=device_cap_bytes,
                           io_driver=io_driver,
                           io_queue_depth=io_queue_depth)
    data = x.reshape(v, n // v)
    if tier != "device":
        data = np.asarray(data)
    res = np.asarray(program(data)).reshape(-1)
    if return_pems:
        return res, pems
    return res
