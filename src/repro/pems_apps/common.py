"""Shared helpers for BSP applications: destination grouping for Alltoallv
message assembly (the "bucketising" every CGM algorithm performs)."""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

INT_MAX = jnp.iinfo(jnp.int32).max


def group_by_dest(
    values: jnp.ndarray,      # [n] or [n, w] payloads
    dests: jnp.ndarray,       # [n] int32 destination VP ids in [0, v)
    v: int,
    cap: int,
    fill=0,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Pack per-element payloads into per-destination message slots.

    Returns ``(msgs [v, cap(, w)], counts [v], slot_pos [n], ok)`` where
    ``slot_pos[i]`` is the position of element ``i`` inside message
    ``msgs[dests[i]]`` (needed to route responses back), and ``ok`` is False
    if any destination received more than ``cap`` elements (capacity
    overflow — the caller's ω bound was violated)."""
    n = dests.shape[0]
    order = jnp.argsort(dests, stable=True)
    sorted_d = dests[order]
    # Start offset of each destination group in the sorted order.
    start = jnp.searchsorted(sorted_d, jnp.arange(v, dtype=dests.dtype))
    pos_sorted = jnp.arange(n, dtype=jnp.int32) - start[sorted_d].astype(jnp.int32)
    counts = jnp.bincount(dests, length=v).astype(jnp.int32)
    ok = counts.max() <= cap

    payload = values if values.ndim > 1 else values[:, None]
    w = payload.shape[1]
    msgs = jnp.full((v, cap, w), fill, payload.dtype)
    safe_pos = jnp.minimum(pos_sorted, cap - 1)  # clamp on overflow; ok=False
    msgs = msgs.at[sorted_d, safe_pos].set(payload[order])

    # slot position for each *original* element.
    inv = jnp.zeros(n, jnp.int32).at[order].set(jnp.arange(n, dtype=jnp.int32))
    slot_pos = safe_pos[inv]

    if values.ndim == 1:
        msgs = msgs[..., 0]
    return msgs, counts, slot_pos, ok


def take_from_slots(msgs: jnp.ndarray, dests: jnp.ndarray,
                    slot_pos: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`group_by_dest` for response routing: element ``i``'s
    response is ``msgs[dests[i], slot_pos[i]]``."""
    return msgs[dests, slot_pos]
