"""Euler tour of a rooted forest on PEMS (thesis §8.4.3, CGMLib app).

Pipeline (each EM-heavy stage is a PEMS program, composed exactly like the
CGMLib application composes its sort and list-ranking primitives):

  1. **PSRS sort** of (parent, child) keys → children of every node become
     contiguous, globally ordered (the doubled-edge adjacency of Fig 8.22).
  2. Decode first-child / next-sibling pointers (local index arithmetic).
  3. Build the Euler successor function over directed-edge IDs
     (down-edge of i = 2i, up-edge = 2i+1):
        succ(2i)   = 2·firstchild(i)          if i has children else 2i+1
        succ(2i+1) = 2·nextsibling(i)         if it exists
                   = terminal                 if parent(i) is a root
                   = 2·parent(i)+1            otherwise
  4. **List ranking** of succ → each edge's distance to its tour's end.

Returns per-edge ranks; ordering a tree's edges by descending rank yields the
Euler tour (Fig 8.23's visit order)."""

from __future__ import annotations

import numpy as np

from .list_ranking import list_rank
from .psrs import psrs_sort


def euler_tour(parent, v: int, k: int = 1, driver: str = "explicit",
               mode: str = "direct"):
    """Compute the Euler tour structure of a forest.

    Args:
      parent: [n] int array, ``parent[r] == r`` for roots; children are
        ordered by node index.
    Returns:
      dict with ``succ`` ([2n] edge successor ids), ``rank`` ([2n] hops to
      tour end), ``valid`` ([2n] bool, False for root pseudo-edges).
    """
    parent = np.asarray(parent, np.int64)
    n = parent.shape[0]
    is_root = parent == np.arange(n)

    # ---- 1. sort (parent, child) pairs of real edges with PSRS ------------
    child = np.arange(n)[~is_root]
    keys = parent[~is_root] * n + child
    # Pad to a multiple of v with +inf-like keys (sorted to the end).
    pad = (-len(keys)) % v
    if len(keys) + pad == 0:
        pad = v
    big = n * n + np.arange(pad)
    keys_padded = np.concatenate([keys, big]).astype(np.int64)
    if keys_padded.max() >= 2**31:
        # 64-bit keys: sort (parent, child) lexicographically in two 32-bit
        # passes would be needed; for the sizes exercised here pack fits.
        raise ValueError("n too large for packed 32-bit PSRS keys")
    sorted_keys = psrs_sort(keys_padded.astype(np.int32), v=v, k=k,
                            driver=driver, mode=mode)
    sorted_keys = np.asarray(sorted_keys, np.int64)[: len(keys)]

    # ---- 2. first-child / next-sibling (local index arithmetic) -----------
    sp = sorted_keys // n
    sc = sorted_keys % n
    firstchild = np.full(n, -1, np.int64)
    nextsib = np.full(n, -1, np.int64)
    if len(sc):
        first_mask = np.ones(len(sc), bool)
        first_mask[1:] = sp[1:] != sp[:-1]
        firstchild[sp[first_mask]] = sc[first_mask]
        same = sp[1:] == sp[:-1]
        nextsib[sc[:-1][same]] = sc[1:][same]

    # ---- 3. edge successor function ---------------------------------------
    succ = np.arange(2 * n, dtype=np.int64)          # default: self (terminal)
    nodes = np.arange(n)
    nonroot = ~is_root
    down = 2 * nodes[nonroot]
    up = down + 1
    has_child = firstchild[nodes[nonroot]] >= 0
    succ[down] = np.where(has_child, 2 * firstchild[nodes[nonroot]], up)
    has_sib = nextsib[nodes[nonroot]] >= 0
    p = parent[nodes[nonroot]]
    parent_is_root = is_root[p]
    succ[up] = np.where(
        has_sib,
        2 * nextsib[nodes[nonroot]],
        np.where(parent_is_root, up, 2 * p + 1),
    )

    # ---- 4. list-rank the tour ---------------------------------------------
    pad2 = (-2 * n) % (2 * v)
    succ_padded = np.concatenate(
        [succ, 2 * n + np.arange(pad2)]
    ).astype(np.int32)
    rank = list_rank(succ_padded, v=v, k=k, driver=driver, mode=mode)
    rank = rank[: 2 * n]

    valid = np.zeros(2 * n, bool)
    valid[down] = True
    valid[up] = True
    return {"succ": succ, "rank": rank, "valid": valid,
            "firstchild": firstchild, "nextsib": nextsib}
