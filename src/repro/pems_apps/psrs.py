"""PSRS — Parallel Sorting by Regular Sampling (thesis Alg 8.3.1) on PEMS.

Four virtual supersteps, exactly the thesis' structure:

  1. local sort + choose v regular samples        (computation)
  2. **Gather** all v² samples at the root
  3. root sorts samples, picks v−1 splitters; **Bcast**
  4. partition local data by splitters; **Alltoallv** counts + buckets
  5. merge received buckets                        (computation)

The final Alltoallv moves the entire data set — it dominates I/O, which is
why PSRS is the thesis' flagship benchmark for direct vs indirect delivery.

Duplicate keys are handled by lexicographic (value, global-index) splitters,
which preserves the 2n/v per-receiver bound even for constant inputs.
"""

from __future__ import annotations

import os
import signal
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ContextLayout, Pems, PemsConfig, SuperstepCursor,
                        atomic_replace_file)
from repro.kernels.bitonic_sort import bitonic_sort
from repro.kernels.kway_merge import kway_merge
from .common import INT_MAX, group_by_dest

# Fields each stage both reads and writes: rerunning such a stage after a
# mid-stage crash would compute from possibly-torn rows, so the recoverable
# runner snapshots them before the stage and restores on a dirty resume.
# Stages absent here have disjoint read/write sets and rerun idempotently.
# (Kept as a side table so ``steps`` stays a plain (name, fn) list.)
STAGE_SNAPSHOT_FIELDS = {
    "sort_sample": ("data",),
    "bcast_splitters": ("gsplit",),
    "merge": ("oflow",),
}


def _build(v: int, k: int, n_v: int, cap, rcap, driver: str,
           mode: str, local_sort, use_kernel: bool = True,
           tier: str = "device", backing_path=None, device_cap_bytes=None,
           P: int = 1, mesh=None, alpha=None,
           io_driver=None, io_queue_depth=None,
           fault_spec=None, checksums: bool = False, io_retries=None,
           merge_kernel=None, merge_tile=None,
           trace: bool = False, trace_path=None):
    # One home for the PSRS capacity defaults: the always-safe per-message
    # bound n/v and the 2n/v per-receiver guarantee.
    cap = n_v if cap is None else cap
    rcap = 2 * n_v if rcap is None else rcap
    # Default local sort: the bitonic kernel (auto backend — compiled Pallas
    # on TPU, jnp.sort on CPU/GPU).  use_kernel=False keeps the seed's
    # jnp.sort on every path; both are bit-identical on int32 keys.
    if local_sort is None:
        local_sort = bitonic_sort if use_kernel else jnp.sort
    lo = (
        ContextLayout()
        .add("data", (n_v,), jnp.int32)
        .add("samp", (v, 2), jnp.int32)        # (value, global index)
        .add("allsamp", (v, v, 2), jnp.int32)
        .add("gsplit", (v, 2), jnp.int32)
        .add("bsend", (v, cap), jnp.int32)
        .add("bscnt", (v,), jnp.int32)
        .add("brecv", (v, cap), jnp.int32)
        .add("brcnt", (v,), jnp.int32)
        .add("result", (rcap,), jnp.int32)
        .add("rcount", (1,), jnp.int32)
        .add("oflow", (1,), jnp.int32)
    )
    io_kw = {}
    if io_driver is not None:
        io_kw["io_driver"] = io_driver
    if io_queue_depth is not None:
        io_kw["io_queue_depth"] = io_queue_depth
    if fault_spec is not None:
        io_kw["fault_spec"] = fault_spec
    if io_retries is not None:
        io_kw["io_retries"] = io_retries
    if checksums:
        io_kw["checksums"] = True
    if merge_kernel is not None:
        io_kw["merge_kernel"] = bool(merge_kernel)
    if merge_tile is not None:
        io_kw["merge_tile"] = merge_tile
    if trace:
        io_kw["trace"] = True
    if trace_path is not None:
        io_kw["trace_path"] = trace_path
    pems = Pems(PemsConfig(v=v, k=k, P=P, driver=driver, tier=tier,
                           backing_path=backing_path, alpha=alpha,
                           device_cap_bytes=device_cap_bytes, **io_kw),
                lo, mesh=mesh)

    def sort_and_sample(rho, ctx):
        data = local_sort(ctx.get("data"))
        # Regular sampling: positions ⌊j·n_v/v⌋, j = 0..v−1 (Shi & Schaeffer).
        idx = (jnp.arange(v) * n_v) // v
        gid = rho * n_v + idx.astype(jnp.int32)
        samp = jnp.stack([data[idx], gid], axis=-1)
        return ctx.set("data", data).set("samp", samp)

    def pick_splitters(rho, ctx):
        allsamp = ctx.get("allsamp").reshape(-1, 2)
        order = jnp.lexsort((allsamp[:, 1], allsamp[:, 0]))
        s = allsamp[order]
        # Splitters at ranks (i+1)·v + v/2 − 1, i = 0..v−2; sentinel at end.
        ii = (jnp.arange(v - 1) + 1) * v + v // 2 - 1
        gs = jnp.concatenate(
            [s[ii], jnp.array([[INT_MAX, INT_MAX]], jnp.int32)]
        )
        return ctx.set("gsplit", gs)

    def partition(rho, ctx):
        data = ctx.get("data")
        gs = ctx.get("gsplit")
        gid = rho * n_v + jnp.arange(n_v, dtype=jnp.int32)
        sv, sg = gs[:-1, 0], gs[:-1, 1]        # v−1 splitters
        # dest = #splitters (sv, sg) <= (x, gid) lexicographically.
        le = (sv[None, :] < data[:, None]) | (
            (sv[None, :] == data[:, None]) & (sg[None, :] <= gid[:, None])
        )
        dest = le.sum(axis=1).astype(jnp.int32)
        msgs, counts, _, ok = group_by_dest(data, dest, v, cap, fill=INT_MAX)
        return (
            ctx.set("bsend", msgs)
            .set("bscnt", counts)
            .set("oflow", (~ok).astype(jnp.int32)[None])
        )

    def merge(rho, ctx):
        # The boundary mask is fused into delivery (alltoallv fill=INT_MAX):
        # lanes past brcnt arrive as INT_MAX, so the received buckets merge
        # as-is — no re-mask pass over the 2n/v received words.
        recv = ctx.get("brecv")              # [v, cap]
        cnt = ctx.get("brcnt")               # [v]
        if pems.cfg.merge_kernel and use_kernel:
            # Tiled k-way merge with exact splitting: O(n log v) over the
            # already-sorted buckets instead of the O(n log n) re-sort, and
            # the overflow flag is raised by the op itself at the stage
            # boundary — the truncation to rcap can never outrun it.
            merged, total, over = kway_merge(
                recv, cnt, rcap=rcap, tile=pems.cfg.merge_tile,
                fill=INT_MAX)
        else:
            flat = recv.reshape(-1)
            merged = local_sort(flat)[:rcap]
            total = cnt.sum()
            over = (total > rcap).astype(jnp.int32)
        return (
            ctx.set("result", merged)
            .set("rcount", total[None].astype(jnp.int32))
            .set("oflow", ctx.get("oflow") | over.astype(jnp.int32)[None])
        )

    # The program as an explicit stage list: the device tier jit-fuses the
    # whole pipeline as before, while backing tiers run it stage-by-stage
    # host-side — and callers (checkpoint tests, resumable jobs) can stop
    # after any stage and resume from a restored store.
    # Every stage accepts an optional ``procs`` (tiered stores only): run the
    # stage for the listed processes' shards alone — the per-process
    # recovery hook psrs_run_recoverable drives after a one-disk failure.
    steps = [
        ("sort_sample", lambda st, procs=None: pems.superstep(
            st, sort_and_sample, reads=["data"], writes=["data", "samp"],
            procs=procs)),
        ("gather_samples", lambda st, procs=None: pems.gather(
            st, "samp", "allsamp", root=0, procs=procs)),
        ("pick_splitters", lambda st, procs=None: pems.superstep(
            st, pick_splitters, reads=["allsamp"], writes=["gsplit"],
            procs=procs)),
        ("bcast_splitters", lambda st, procs=None: pems.bcast(
            st, "gsplit", root=0, procs=procs)),
        ("partition", lambda st, procs=None: pems.superstep(
            st, partition, reads=["data", "gsplit"],
            writes=["bsend", "bscnt", "oflow"], procs=procs)),
        ("alltoallv", lambda st, procs=None: pems.alltoallv(
            st, "bsend", "brecv", "bscnt", "brcnt",
            mode=mode, fill=INT_MAX, use_kernel=use_kernel, procs=procs)),
        # stream=True: on a disk backing the merge's bucket reads are
        # prefetched through the block API while the previous round merges,
        # under every driver (TierStats.merge_prefetch_events counts them).
        ("merge", lambda st, procs=None: pems.superstep(
            st, merge, reads=["brecv", "brcnt", "oflow"],
            writes=["result", "rcount", "oflow"], procs=procs,
            stream=True)),
    ]

    # Stage spans on the main tracer's "stages" lane: one per plan stage,
    # the unit the obs report attributes compute/I-O/stall time to.  With
    # tracing off pems.tracer is the no-op singleton, so the wrapper costs
    # one attribute check per stage (and is jit-transparent).
    def _staged(name, fn):
        def run(st, procs=None):
            with pems.tracer.span(f"stage:{name}", tid="stages",
                                  cat="stage"):
                return fn(st, procs=procs)
        return run

    steps = [(name, _staged(name, fn)) for name, fn in steps]

    def load(data_blocks):                  # [v, n_v] int32
        return pems.init().with_field("data", data_blocks)

    def extract(store):
        return (store.field("result"), store.field("rcount"),
                store.field("oflow"))

    def program(data_blocks):
        store = load(data_blocks)
        for _, step in steps:
            store = step(store)
        return extract(store)

    # The P > 1 mesh path runs the stages eagerly (each superstep/collective
    # shard_maps and jits internally); the single-process device tier still
    # jit-fuses the whole pipeline as the seed did.  Tracing forces the
    # eager path — spans inside a jitted program would fire once at trace
    # time and never again (results are bit-identical either way).
    if tier == "device" and P == 1 and not pems.cfg.trace:
        program = jax.jit(program)
    return pems, program, (load, steps, extract)


def psrs_plan(
    v: int,
    n_v: int,
    k: int = 1,
    driver: str = "explicit",
    mode: str = "direct",
    cap: Optional[int] = None,
    rcap: Optional[int] = None,
    local_sort=None,
    use_kernel: bool = True,
    tier: str = "device",
    backing_path=None,
    device_cap_bytes=None,
    P: int = 1,
    mesh=None,
    alpha=None,
    io_driver=None,
    io_queue_depth=None,
    fault_spec=None,
    checksums: bool = False,
    io_retries=None,
    merge_kernel: Optional[bool] = None,
    merge_tile: Optional[int] = None,
    trace: bool = False,
    trace_path: Optional[str] = None,
):
    """Stepwise PSRS: returns ``(pems, load, steps, extract)``.

    ``load([v, n_v] int32) -> store`` initialises the population;
    ``steps`` is a list of named ``store -> store`` stages (run them in
    order, or stop after any stage, checkpoint the backing store, and
    resume later); ``extract(store) -> (result, rcount, oflow)``.

    ``trace=True`` records structured spans (stages, executor rounds, I/O
    requests, collective chunks) into ``pems.tracer``; export with
    ``pems.export_trace(path)`` (or set ``trace_path`` — :func:`psrs_sort`
    / :func:`psrs_run_recoverable` then export automatically).
    """
    pems, _, (load, steps, extract) = _build(
        v, k, n_v, cap, rcap, driver, mode, local_sort,
        use_kernel=use_kernel, tier=tier, backing_path=backing_path,
        device_cap_bytes=device_cap_bytes, P=P, mesh=mesh, alpha=alpha,
        io_driver=io_driver, io_queue_depth=io_queue_depth,
        fault_spec=fault_spec, checksums=checksums, io_retries=io_retries,
        merge_kernel=merge_kernel, merge_tile=merge_tile,
        trace=trace, trace_path=trace_path,
    )
    return pems, load, steps, extract


def psrs_sort(
    keys,
    v: int,
    k: int = 1,
    driver: str = "explicit",
    mode: str = "direct",
    cap: Optional[int] = None,
    rcap: Optional[int] = None,
    local_sort=None,
    return_pems: bool = False,
    use_kernel: bool = True,
    tier: str = "device",
    backing_path=None,
    device_cap_bytes=None,
    P: int = 1,
    mesh=None,
    alpha=None,
    io_driver=None,
    io_queue_depth=None,
    fault_spec=None,
    checksums: bool = False,
    io_retries=None,
    merge_kernel: Optional[bool] = None,
    merge_tile: Optional[int] = None,
    trace: bool = False,
    trace_path: Optional[str] = None,
):
    """Sort int32 ``keys`` ([n], n divisible by v) with PSRS on PEMS.

    ``mode`` selects PEMS2 direct delivery or the PEMS1 indirect baseline for
    the final Alltoallv; ``cap`` is the per-(sender,dest) message capacity ω
    (defaults to the always-safe n/v) and ``rcap`` the per-receiver capacity
    (defaults to the PSRS guarantee 2n/v).  ``use_kernel`` toggles the
    kernel paths end to end — the fused Pallas delivery in the final
    Alltoallv, the bitonic local sort, and the tiled k-way merge; ``False``
    keeps the seed's dense/jnp.sort routes (results are bit-identical
    either way; kept for equivalence testing).  ``merge_kernel``/
    ``merge_tile`` (defaults from :class:`~repro.core.PemsConfig`) control
    the merge stage alone: the exact-splitter tiled merge of the v received
    sorted buckets — O(n log v) instead of the dense O(n log n) re-sort —
    in ``merge_tile``-wide output tiles, with its input buckets streamed
    through the backing block API on disk tiers so merge compute overlaps
    the reads (``pems.tier_stats.merge_prefetch_events``).  ``local_sort``
    overrides the local-sort primitive (default: the ``bitonic_sort``
    kernel with automatic backend dispatch; ``jnp.sort`` when
    ``use_kernel=False``).

    ``tier`` selects where the context population lives: ``"device"`` (the
    seed in-memory path, whole program jitted), ``"host"`` (host RAM),
    ``"memmap"`` (a disk backing file at ``backing_path``) or ``"file"``
    (the same file reached through the :mod:`repro.io` async engine —
    ``io_driver`` picks ``buffered``/``odirect``/``mmap``,
    ``io_queue_depth`` bounds in-flight requests) — the out-of-core paths,
    host-driven with only k·μ device-resident at a time, optionally
    enforced via ``device_cap_bytes``.  All tiers sort bit-identically.

    ``P``/``mesh`` run the simulation over ``P`` real processors: each
    process owns ``v/P`` contexts.  On the device tier a jax mesh with the
    ``vp`` axis is required and the final Alltoallv's network phase is
    α-chunked over the mesh (``alpha``, Alg 7.1.3) — through the fused
    (src_proc, dst_proc)-tiled delivery kernel by default, bit-identical to
    the dense ``use_kernel=False`` route and to the ``P == 1`` reference.
    On a backing tier ``P > 1`` needs no mesh: the backing is *sharded* —
    each process owns its own ``v/P``-row backing file
    (``backing_path + ".shard<p>"``, its own I/O engine on ``tier="file"``)
    and the round pipeline and collectives run per process, staging the
    network phase through per-process host buffers.  Per-shard traffic is
    measured in ``pems.shard_ledgers[p]``/``pems.shard_stats[p]`` and sums
    to the ``P == 1`` totals; results stay bit-identical.

    ``trace=True`` records structured spans for the whole run — per-stage
    and per-superstep, executor rounds (compute vs swap_in/swap_out vs
    stall), per-request engine I/O, collective chunks — in the
    :mod:`repro.obs` tracer (device-tier ``P == 1`` then runs eagerly
    instead of whole-program jit; results are bit-identical).  With
    ``trace_path`` set the merged Chrome/Perfetto trace (plus a metrics
    snapshot) is written there on completion; inspect with
    ``python -m repro.obs report <path>``.

    Raises ``ValueError`` for n not divisible by v (and for any invalid
    :class:`~repro.core.PemsConfig` combination) and ``OverflowError``
    when a bucket exceeds ``cap``/``rcap``.
    """
    keys = jnp.asarray(keys, jnp.int32)
    n = keys.shape[0]
    if n % v:
        raise ValueError(f"n={n} must be divisible by v={v}")
    n_v = n // v
    pems, program, _ = _build(v, k, n_v, cap, rcap, driver, mode, local_sort,
                              use_kernel=use_kernel, tier=tier,
                              backing_path=backing_path,
                              device_cap_bytes=device_cap_bytes,
                              P=P, mesh=mesh, alpha=alpha,
                              io_driver=io_driver,
                              io_queue_depth=io_queue_depth,
                              fault_spec=fault_spec, checksums=checksums,
                              io_retries=io_retries,
                              merge_kernel=merge_kernel,
                              merge_tile=merge_tile,
                              trace=trace, trace_path=trace_path)
    data = keys.reshape(v, n_v)
    if tier != "device":
        data = np.asarray(data)
    result, rcount, oflow = program(data)
    if pems.cfg.trace_path is not None:
        pems.export_trace()
    result = np.asarray(result)
    rcount = np.asarray(rcount)[:, 0]
    if np.asarray(oflow).any():
        raise OverflowError(
            "PSRS message capacity exceeded; raise cap/rcap "
            f"(cap={cap}, rcap={rcap})"
        )
    out = np.concatenate([result[i, : rcount[i]] for i in range(v)])
    if return_pems:
        return out, pems
    return out


def _snapshot_path(state_dir: str, proc: int = 0, nprocs: int = 1) -> str:
    """Per-process snapshot file; the bare legacy name at ``nprocs == 1``
    so existing single-process state dirs resume unchanged."""
    if nprocs == 1:
        return os.path.join(state_dir, "stage_snapshot.npz")
    return os.path.join(state_dir, f"stage_snapshot.p{proc}.npz")


def _save_snapshot(state_dir: str, stage: int, fields: dict,
                   proc: int = 0, nprocs: int = 1) -> None:
    """Atomically persist the pre-stage copy of the stage's read∩write
    fields (restored before a dirty rerun — see STAGE_SNAPSHOT_FIELDS).
    At ``nprocs > 1`` the fields hold process ``proc``'s shard rows only."""
    path = _snapshot_path(state_dir, proc, nprocs)
    atomic_replace_file(
        path, lambda f: np.savez(f, __stage__=np.int64(stage), **fields),
        binary=True)


def _load_snapshot(state_dir: str, stage: int,
                   proc: int = 0, nprocs: int = 1):
    """The snapshot's field dict, iff it belongs to ``stage``."""
    try:
        with np.load(_snapshot_path(state_dir, proc, nprocs)) as z:
            if int(z["__stage__"]) != stage:
                return None
            return {k: z[k] for k in z.files if k != "__stage__"}
    except (OSError, ValueError, KeyError):
        return None


def psrs_run_recoverable(
    keys,
    v: int,
    *,
    state_dir: str,
    k: int = 1,
    P: int = 1,
    alpha: Optional[int] = None,
    driver: str = "explicit",
    mode: str = "direct",
    cap: Optional[int] = None,
    rcap: Optional[int] = None,
    local_sort=None,
    use_kernel: bool = True,
    tier: str = "file",
    io_driver=None,
    io_queue_depth=None,
    fault_spec=None,
    checksums: bool = True,
    io_retries=None,
    device_cap_bytes=None,
    crash_after_stage=None,
    crash_in_stage=None,
    return_pems: bool = False,
    merge_kernel: Optional[bool] = None,
    merge_tile: Optional[int] = None,
    trace: bool = False,
    trace_path: Optional[str] = None,
):
    """PSRS with durable superstep recovery: survives ``kill -9``.

    Runs the :func:`psrs_plan` stages against a backing file in
    ``state_dir``, recording a durable :class:`SuperstepCursor` around every
    stage and an atomic pre-stage snapshot of the fields the stage both
    reads and writes (see ``STAGE_SNAPSHOT_FIELDS`` — rerunning those from
    possibly-torn rows would be garbage-from-garbage).  Killed at *any*
    point — between stages, mid-stage, even mid-``pwrite`` — a rerun with
    the same arguments resumes from the last completed stage and produces
    output bit-identical to an uninterrupted run.

    ``P > 1`` runs the parallel disk model: the backing is sharded into
    ``P`` per-process files (each with its own engine on ``tier="file"``)
    and recovery state is **per process** — one cursor
    (``cursor.p<p>.json``) and one snapshot per shard, each stage committed
    shard by shard (run with ``procs=[p]``, flushed via the shard's own
    backing).  A failure on one shard's disk — e.g. a
    ``fault_spec="shard=1;..."`` injection — leaves the other processes'
    cursors at the completed stage; the rerun re-executes only the failed
    process's stage against its own shard, without touching (or re-running)
    the healthy shards.  Output stays bit-identical to the ``P == 1`` run.

    ``checksums`` (default on) adds per-block CRCs to the backing file so a
    torn write in the in-progress stage is detected and healed by the rerun
    instead of silently merged; a torn write can only live in the
    in-progress stage because completed stages are flushed before their
    cursor commit.

    ``crash_after_stage`` / ``crash_in_stage`` (stage name or index;
    ``"load"`` is stage 0) SIGKILL the process at the stage boundary /
    between the stage's compute and its flush (at ``P > 1``: after the
    last process's compute, so earlier processes have already committed) —
    the chaos-test hooks.

    Raises ``ValueError`` for a non-disk ``tier`` or n not divisible by v,
    and ``OverflowError`` when a bucket exceeds ``cap``/``rcap``.
    """
    keys = np.asarray(keys, np.int32)
    n = keys.size
    if n % v:
        raise ValueError(f"n={n} must be divisible by v={v}")
    if tier not in ("memmap", "file"):
        raise ValueError(
            f"recovery needs a disk tier ('memmap' or 'file'), got {tier!r}")
    n_v = n // v
    os.makedirs(state_dir, exist_ok=True)
    backing_path = os.path.join(state_dir, "ctx.bin")
    pems, _load_unused, steps, extract = psrs_plan(
        v, n_v, k=k, P=P, alpha=alpha, driver=driver, mode=mode,
        cap=cap, rcap=rcap,
        local_sort=local_sort, use_kernel=use_kernel, tier=tier,
        backing_path=backing_path, device_cap_bytes=device_cap_bytes,
        io_driver=io_driver, io_queue_depth=io_queue_depth,
        fault_spec=fault_spec, checksums=checksums, io_retries=io_retries,
        merge_kernel=merge_kernel, merge_tile=merge_tile,
        trace=trace, trace_path=trace_path)

    m_ctx = v // P                        # contexts per process
    data_blocks = keys.reshape(v, n_v)

    # "load" is stage 0 (idempotent: rewrites data from the caller's input).
    # pems.init() runs exactly once below, so load goes through with_field
    # rather than psrs_plan's own load() (which would init a second engine
    # on the same backing file).
    def load_stage(st, procs=None):
        for p in (range(P) if procs is None else procs):
            st = st.with_field_rows(
                "data", p * m_ctx, data_blocks[p * m_ctx:(p + 1) * m_ctx])
        return st

    stages = [("load", load_stage)] + list(steps)

    def _stage_index(which):
        if which is None:
            return None
        if isinstance(which, str):
            for i, (name, _) in enumerate(stages):
                if name == which:
                    return i
            raise ValueError(f"unknown stage {which!r}")
        return int(which)

    crash_after = _stage_index(crash_after_stage)
    crash_in = _stage_index(crash_in_stage)

    cursors = [SuperstepCursor(SuperstepCursor.path_for(state_dir, p, P))
               for p in range(P)]
    for p, cur in enumerate(cursors):
        cur.tracer = pems.tracer
        cur.trace_tid = f"recovery.p{p}" if P > 1 else "recovery"
    pems.cursors = cursors

    store = pems.init()      # create-or-reuse: committed rows are kept
    bk = store.backing
    for p in range(P):
        st = cursors[p].state()
        in_prog = None if st is None else st.get("in_progress")
        if in_prog is None:
            continue
        if getattr(bk, "checksum", None) is not None:
            # The sidecar records *intended* CRCs for writes the crash may
            # have torn; those rows belong to the in-progress stage and are
            # about to be regenerated, so re-bless the bytes on disk —
            # only the dirty process's shard under a sharded backing.
            if hasattr(bk, "shards"):
                bk.recompute_checksums(shard=p)
            else:
                bk.recompute_checksums()
        snap = _load_snapshot(state_dir, int(in_prog), p, P)
        if snap is not None:
            with pems.tracer.span("snapshot:restore", tid="recovery",
                                  cat="recovery", proc=p,
                                  stage=int(in_prog)):
                for fname, val in snap.items():
                    store = store.with_field_rows(fname, p * m_ctx, val)

    for i, (name, fn) in enumerate(stages):
        todo = [p for p in range(P) if i > cursors[p].completed]
        for p in todo:
            fields = STAGE_SNAPSHOT_FIELDS.get(name, ())
            if fields:
                with pems.tracer.span("snapshot:save", tid="recovery",
                                      cat="recovery", proc=p, stage=i):
                    _save_snapshot(
                        state_dir, i,
                        {f: np.asarray(
                            store.field_rows(f, p * m_ctx, (p + 1) * m_ctx))
                         for f in fields},
                        p, P)
            cursors[p].mark_in_progress(i, name)
            store = fn(store, procs=[p])
            if crash_in == i and p == todo[-1]:
                os.kill(os.getpid(), signal.SIGKILL)
            # Commit this process's writes only: its shard's backing (and
            # sidecar) flush before its cursor advances.  Stages write
            # nothing outside the listed shard, so the other processes'
            # committed bytes are untouched either way.
            if hasattr(bk, "flush_shard"):
                bk.flush_shard(p)
            else:
                store.flush()
            cursors[p].mark_completed(i, name)
        if todo and crash_after == i:
            os.kill(os.getpid(), signal.SIGKILL)

    if pems.cfg.trace_path is not None:
        pems.export_trace()
    result, rcount, oflow = extract(store)
    result = np.asarray(result)
    rcount = np.asarray(rcount)[:, 0]
    if np.asarray(oflow).any():
        raise OverflowError(
            "PSRS message capacity exceeded; raise cap/rcap "
            f"(cap={cap}, rcap={rcap})"
        )
    out = np.concatenate([result[i, : rcount[i]] for i in range(v)])
    if return_pems:
        return out, pems
    return out
