"""CGM list ranking on PEMS via pointer jumping (used by the Euler-tour
application, thesis §8.4.3; CGMLib provides the same primitive).

Each of ⌈log₂ n⌉ rounds is a request/response pair of Alltoallvs: every
element asks the owner of its successor for ``(rank[succ], succ[succ])`` and
then jumps.  Terminals are fixpoints (``succ[i] == i``); on convergence
``rank[i]`` is the number of hops from i to its list's terminal — for a
forest of lists every list is ranked independently (exactly what the Euler
tour needs)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ContextLayout, Pems, PemsConfig
from .common import group_by_dest


def _build(v: int, k: int, n_v: int, rounds: int, driver: str, mode: str):
    cap = n_v  # worst case: all of a VP's successors live on one owner
    lo = (
        ContextLayout()
        .add("succ", (n_v,), jnp.int32)
        .add("rank", (n_v,), jnp.int32)
        .add("dest", (n_v,), jnp.int32)
        .add("spos", (n_v,), jnp.int32)
        .add("qs", (v, cap), jnp.int32)    # request send (global indices)
        .add("qscnt", (v,), jnp.int32)
        .add("qr", (v, cap), jnp.int32)    # request recv
        .add("qrcnt", (v,), jnp.int32)
        .add("as_", (v, cap, 2), jnp.int32)  # answer send (rank, succ)
        .add("ascnt", (v,), jnp.int32)
        .add("ar", (v, cap, 2), jnp.int32)   # answer recv
        .add("arcnt", (v,), jnp.int32)
    )
    pems = Pems(PemsConfig(v=v, k=k, driver=driver), lo)

    def make_requests(rho, ctx):
        succ = ctx.get("succ")
        dest = succ // n_v
        msgs, counts, spos, _ = group_by_dest(succ, dest, v, cap)
        return (ctx.set("qs", msgs).set("qscnt", counts)
                .set("dest", dest).set("spos", spos))

    def answer(rho, ctx):
        req = ctx.get("qr")                    # [v, cap] global indices
        cnt = ctx.get("qrcnt")
        local = jnp.clip(req - rho * n_v, 0, n_v - 1)
        r = ctx.get("rank")[local]             # [v, cap]
        s = ctx.get("succ")[local]
        ans = jnp.stack([r, s], axis=-1)
        return ctx.set("as_", ans).set("ascnt", cnt)

    def jump(rho, ctx):
        ans = ctx.get("ar")                    # [v, cap, 2]
        dest, spos = ctx.get("dest"), ctx.get("spos")
        got = ans[dest, spos]                  # [n_v, 2]
        succ = ctx.get("succ")
        rank = ctx.get("rank")
        gid = rho * n_v + jnp.arange(n_v, dtype=jnp.int32)
        live = succ != gid
        rank = jnp.where(live, rank + got[:, 0], rank)
        succ = jnp.where(live, got[:, 1], succ)
        return ctx.set("succ", succ).set("rank", rank)

    def program(succ_blocks):
        store = pems.init().with_field("succ", succ_blocks)
        gid = jnp.arange(v * n_v, dtype=jnp.int32).reshape(v, n_v)
        store = store.with_field(
            "rank", (succ_blocks != gid).astype(jnp.int32)
        )
        for _ in range(rounds):
            store = pems.superstep(store, make_requests,
                                   reads=["succ"],
                                   writes=["qs", "qscnt", "dest", "spos"])
            store = pems.alltoallv(store, "qs", "qr", "qscnt", "qrcnt",
                                   mode=mode)
            store = pems.superstep(store, answer,
                                   reads=["qr", "qrcnt", "rank", "succ"],
                                   writes=["as_", "ascnt"])
            store = pems.alltoallv(store, "as_", "ar", "ascnt", "arcnt",
                                   mode=mode)
            store = pems.superstep(store, jump,
                                   reads=["ar", "dest", "spos", "succ", "rank"],
                                   writes=["succ", "rank"])
        return store.field("rank"), store.field("succ")

    return pems, jax.jit(program)


def list_rank(succ, v: int, k: int = 1, driver: str = "explicit",
              mode: str = "direct", return_pems: bool = False):
    """Rank the linked list(s) ``succ`` ([n] global successor indices,
    terminals are self-loops).  Returns ``rank`` ([n]: hops to terminal)."""
    succ = jnp.asarray(succ, jnp.int32)
    n = succ.shape[0]
    if n % v:
        raise ValueError(f"n={n} must be divisible by v={v}")
    n_v = n // v
    rounds = max(1, math.ceil(math.log2(n)))
    pems, program = _build(v, k, n_v, rounds, driver, mode)
    rank, _ = program(succ.reshape(v, n_v))
    rank = np.asarray(rank).reshape(-1)
    if return_pems:
        return rank, pems
    return rank
