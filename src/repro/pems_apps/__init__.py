"""BSP applications running on the PEMS executor (thesis Chapter 8)."""

from .psrs import psrs_plan, psrs_sort
from .prefix_sum import prefix_sum
from .list_ranking import list_rank
from .euler_tour import euler_tour

__all__ = ["psrs_plan", "psrs_sort", "prefix_sum", "list_rank", "euler_tour"]
