"""BSP applications running on the PEMS executor (thesis Chapter 8)."""

from .psrs import (
    STAGE_SNAPSHOT_FIELDS,
    psrs_plan,
    psrs_run_recoverable,
    psrs_sort,
)
from .prefix_sum import prefix_sum
from .list_ranking import list_rank
from .euler_tour import euler_tour

__all__ = ["STAGE_SNAPSHOT_FIELDS", "psrs_plan", "psrs_run_recoverable",
           "psrs_sort", "prefix_sum", "list_rank", "euler_tour"]
