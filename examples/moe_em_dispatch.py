"""MoE expert dispatch as external-memory Alltoallv.

Experts are the thesis' virtual processors: tokens are bucketised by
destination expert under a capacity bound ω (thesis §6.4) and delivered
directly into per-expert buffers.  The hierarchical grouping (one group per
data-parallel shard) is the thesis' real/virtual processor split — under
pjit the group dim stays sharded and the dispatch lowers to the same
all-to-all EM-Alltoallv-Par performs.

    PYTHONPATH=src python examples/moe_em_dispatch.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.blocks import moe_apply, moe_apply_dense_oracle, moe_params

cfg = get_config("kimi-k2-1t-a32b").smoke()
print(f"MoE: {cfg.n_experts} experts, top-{cfg.top_k}, "
      f"capacity_factor={cfg.capacity_factor}")

params = moe_params(jax.random.PRNGKey(0), cfg)
x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 32, cfg.d_model)),
                jnp.float32)

# EM dispatch (grouped, capacity-bounded) vs the dense all-experts oracle.
for groups in (1, 2, 4):
    y, aux = moe_apply(cfg, params, x, n_groups=groups)
    oracle = moe_apply_dense_oracle(cfg, params, x)
    err = float(jnp.abs(y - oracle).max())
    print(f"groups={groups}: max |EM - oracle| = {err:.2e}  (aux={float(aux):.3f})")

# Capacity pressure → token dropping, like exceeding the thesis' ω bound.
tight = dataclasses.replace(cfg, capacity_factor=0.25)
y_t, _ = moe_apply(tight, params, x, n_groups=2)
print(f"capacity_factor=0.25 drops tokens: output moved by "
      f"{float(jnp.abs(y_t - oracle).max()):.3f} (finite: "
      f"{bool(jnp.isfinite(y_t).all())})")
