"""Quickstart: sort data far bigger than "memory" with a BSP algorithm.

The PSRS sorting algorithm is written for v=16 virtual processors; the PEMS2
executor runs it with only k=4 contexts resident at a time, delivering
messages directly into destination contexts (thesis §6.2) and metering every
byte of simulated external-memory traffic.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.pems_apps import psrs_sort

n = 1 << 20
rng = np.random.default_rng(0)
data = rng.integers(-2**31, 2**31 - 1, size=n, dtype=np.int32)

out, pems = psrs_sort(data, v=16, k=4, return_pems=True)
assert (out == np.sort(data)).all()

led = pems.ledger
print(f"sorted {n:,} int32s with v={pems.cfg.v} virtual processors, "
      f"k={pems.cfg.k} resident")
print(f"  context size mu        : {pems.layout.mu_bytes:,} bytes")
print(f"  swap I/O               : {led.swap_total:,} bytes")
print(f"  direct message delivery: {led.msg_direct:,} bytes")
print(f"  indirect (late) deliver: {led.msg_indirect:,} bytes")
print(f"  external-memory footprint: {led.disk_space:,} bytes "
      f"(PEMS1 would need {led.disk_space + pems.cfg.v * pems.layout.mu_bytes:,})")
print(f"  superstep barriers     : {led.supersteps}")
