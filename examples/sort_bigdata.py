"""Out-of-core sorting: PSRS over a context store larger than the device.

The store is put on the ``memmap`` backing tier — the full ``v·mu`` context
population lives in a file on disk, and only each round's ``k·mu`` is ever
device-resident.  ``DEVICE_CAP_BYTES`` enforces the budget: the population is
more than 4x the cap, so the in-memory path physically could not run under
it, yet the sort is bit-identical to the all-in-memory run.  The ``async``
driver's prefetch thread overlaps each round's disk/PCIe swap-in with the
previous round's compute (thesis §5.1).

With ``--io-driver`` the sort additionally runs on the ``file`` tier — the
same backing file reached through the :mod:`repro.io` async engine
(``buffered`` page-cached pread/pwrite, ``odirect`` page-cache-bypassing
O_DIRECT, or the ``mmap`` adapter), printing the engine's measured queue
depth, read+write overlap events, and syscall-level byte counts.

    PYTHONPATH=src python examples/sort_bigdata.py
    PYTHONPATH=src python examples/sort_bigdata.py --io-driver odirect
    PYTHONPATH=src python examples/sort_bigdata.py --io-driver all
"""

import argparse
import os
import tempfile
import time

import numpy as np

from repro.pems_apps import psrs_sort

ap = argparse.ArgumentParser()
ap.add_argument("--io-driver", default=None,
                choices=("buffered", "odirect", "mmap", "all"),
                help="also sort on tier='file' with this repro.io driver "
                     "('all' sweeps the three)")
ap.add_argument("--io-queue-depth", type=int, default=8)
args = ap.parse_args()

n = 1 << 20
v, k = 16, 1   # k=1: the async tier keeps 3·k·mu in flight, capped below
rng = np.random.default_rng(1)
data = rng.integers(-2**31, 2**31 - 1, size=n, dtype=np.int32)
want = np.sort(data)

# All-in-memory reference (the seed path, tier="device").
t0 = time.perf_counter()
ref, pems_ref = psrs_sort(data, v=v, k=k, driver="async", return_pems=True)
t_ref = time.perf_counter() - t0
assert (ref == want).all()
store_bytes = pems_ref.cfg.v * pems_ref.layout.mu_bytes

# Device-memory cap: the k resident contexts fit, the population does not.
DEVICE_CAP_BYTES = store_bytes // 4 - 1
print(f"context store : {store_bytes / 1e6:8.1f} MB (v={v}, mu="
      f"{pems_ref.layout.mu_bytes / 1e6:.1f} MB)")
print(f"device cap    : {DEVICE_CAP_BYTES / 1e6:8.1f} MB "
      f"(store is {store_bytes / DEVICE_CAP_BYTES:.1f}x larger)\n")

print(f"{'tier':8s} {'driver':10s} {'wall_s':>7s} {'disk_read':>12s} "
      f"{'disk_write':>12s} {'overlap':>8s}")
print(f"{'device':8s} {'async':10s} {t_ref:7.2f} {'-':>12s} {'-':>12s} "
      f"{'-':>8s}")

with tempfile.TemporaryDirectory() as td:
    for driver in ("explicit", "async"):
        t0 = time.perf_counter()
        out, pems = psrs_sort(
            data, v=v, k=k, driver=driver,
            tier="memmap", backing_path=os.path.join(td, f"{driver}.bin"),
            device_cap_bytes=DEVICE_CAP_BYTES,
            return_pems=True,
        )
        dt = time.perf_counter() - t0
        assert (out == ref).all(), "out-of-core sort diverged from in-memory"
        led, ts = pems.ledger, pems.tier_stats
        print(f"{'memmap':8s} {driver:10s} {dt:7.2f} "
              f"{led.disk_read_bytes:12,} {led.disk_write_bytes:12,} "
              f"{ts.overlap_fraction:8.2%}")

    if args.io_driver is not None:
        io_drivers = (("buffered", "odirect", "mmap")
                      if args.io_driver == "all" else (args.io_driver,))
        print(f"\nfile tier (repro.io engine, queue depth "
              f"{args.io_queue_depth}):")
        print(f"{'io_driver':10s} {'driver':10s} {'wall_s':>7s} "
              f"{'syscall_rd':>12s} {'syscall_wr':>12s} {'overlap':>8s} "
              f"{'depth':>5s} {'rw_ovl':>6s}")
        for io_driver in io_drivers:
            for driver in ("explicit", "async"):
                t0 = time.perf_counter()
                out, pems = psrs_sort(
                    data, v=v, k=k, driver=driver, tier="file",
                    io_driver=io_driver,
                    io_queue_depth=args.io_queue_depth,
                    backing_path=os.path.join(
                        td, f"{io_driver}-{driver}.bin"),
                    device_cap_bytes=DEVICE_CAP_BYTES,
                    return_pems=True,
                )
                dt = time.perf_counter() - t0
                assert (out == ref).all(), \
                    "file-tier sort diverged from in-memory"
                led, ts = pems.ledger, pems.tier_stats
                print(f"{io_driver:10s} {driver:10s} {dt:7.2f} "
                      f"{led.syscall_read_bytes:12,} "
                      f"{led.syscall_write_bytes:12,} "
                      f"{ts.overlap_fraction:8.2%} {ts.max_queue_depth:5d} "
                      f"{ts.rw_overlap_events:6d}")

print("\nout-of-core result bit-identical to the in-memory run")

print("\nPEMS2 direct vs PEMS1 indirect delivery (same sort, device tier):")
for mode in ("direct", "indirect"):
    t0 = time.perf_counter()
    out, pems = psrs_sort(data, v=16, k=4, mode=mode, return_pems=True)
    dt = time.perf_counter() - t0
    led = pems.ledger
    print(f"  {mode:9s} wall={dt:6.2f}s io={led.io_total:14,} "
          f"disk={led.disk_space:14,}")
