"""Out-of-core sorting with the three I/O drivers (thesis Ch. 5 + Fig 8.1).

Same PSRS program, three swap strategies:
  explicit — every round swaps the full live context (UNIX driver)
  async    — double-buffered rounds (STXXL driver)
  sliced   — only declared fields move (mmap driver)

    PYTHONPATH=src python examples/sort_bigdata.py
"""

import time

import numpy as np

from repro.pems_apps import psrs_sort

n = 1 << 20
rng = np.random.default_rng(1)
data = rng.integers(-2**31, 2**31 - 1, size=n, dtype=np.int32)
want = np.sort(data)

print(f"{'driver':10s} {'wall_s':>8s} {'swap_bytes':>14s} {'total_io':>14s}")
for driver in ("explicit", "async", "sliced"):
    t0 = time.perf_counter()
    out, pems = psrs_sort(data, v=16, k=4, driver=driver, return_pems=True)
    dt = time.perf_counter() - t0
    assert (out == want).all()
    led = pems.ledger
    print(f"{driver:10s} {dt:8.2f} {led.swap_total:14,} {led.io_total:14,}")

print("\nPEMS2 direct vs PEMS1 indirect delivery (same sort):")
for mode in ("direct", "indirect"):
    t0 = time.perf_counter()
    out, pems = psrs_sort(data, v=16, k=4, mode=mode, return_pems=True)
    dt = time.perf_counter() - t0
    led = pems.ledger
    print(f"  {mode:9s} wall={dt:6.2f}s io={led.io_total:14,} "
          f"disk={led.disk_space:14,}")
