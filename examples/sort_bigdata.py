"""Out-of-core sorting: PSRS over a context store larger than the device.

The store is put on the ``memmap`` backing tier — the full ``v·mu`` context
population lives in a file on disk, and only each round's ``k·mu`` is ever
device-resident.  ``DEVICE_CAP_BYTES`` enforces the budget: the population is
more than 4x the cap, so the in-memory path physically could not run under
it, yet the sort is bit-identical to the all-in-memory run.  The ``async``
driver's prefetch thread overlaps each round's disk/PCIe swap-in with the
previous round's compute (thesis §5.1).

With ``--io-driver`` the sort additionally runs on the ``file`` tier — the
same backing file reached through the :mod:`repro.io` async engine
(``buffered`` page-cached pread/pwrite, ``odirect`` page-cache-bypassing
O_DIRECT, or the ``mmap`` adapter), printing the engine's measured queue
depth, read+write overlap events, and syscall-level byte counts.

With ``--inject-faults`` the sort also demonstrates the fault-tolerance
layer: a run through the deterministic fault-injecting driver (seeded EIO
bursts + latency spikes, absorbed by the engine's bounded retries), then a
genuine ``kill -9`` mid-stage followed by a resume from the durable
superstep cursor — bit-identical to an uninterrupted run.

    PYTHONPATH=src python examples/sort_bigdata.py
    PYTHONPATH=src python examples/sort_bigdata.py --io-driver odirect
    PYTHONPATH=src python examples/sort_bigdata.py --io-driver all
    PYTHONPATH=src python examples/sort_bigdata.py --inject-faults
"""

import argparse
import os
import signal
import subprocess
import sys
import tempfile
import textwrap
import time

import numpy as np

from repro.pems_apps import psrs_run_recoverable, psrs_sort

ap = argparse.ArgumentParser()
ap.add_argument("--io-driver", default=None,
                choices=("buffered", "odirect", "mmap", "all"),
                help="also sort on tier='file' with this repro.io driver "
                     "('all' sweeps the three)")
ap.add_argument("--io-queue-depth", type=int, default=8)
ap.add_argument("--inject-faults", action="store_true",
                help="demonstrate the fault-tolerance layer: survive seeded "
                     "EIO bursts via engine retries, then kill -9 the sort "
                     "mid-stage and resume it bit-identically")
args = ap.parse_args()

n = 1 << 20
v, k = 16, 1   # k=1: the async tier keeps 3·k·mu in flight, capped below
rng = np.random.default_rng(1)
data = rng.integers(-2**31, 2**31 - 1, size=n, dtype=np.int32)
want = np.sort(data)

# All-in-memory reference (the seed path, tier="device").
t0 = time.perf_counter()
ref, pems_ref = psrs_sort(data, v=v, k=k, driver="async", return_pems=True)
t_ref = time.perf_counter() - t0
assert (ref == want).all()
store_bytes = pems_ref.cfg.v * pems_ref.layout.mu_bytes

# Device-memory cap: the k resident contexts fit, the population does not.
DEVICE_CAP_BYTES = store_bytes // 4 - 1
print(f"context store : {store_bytes / 1e6:8.1f} MB (v={v}, mu="
      f"{pems_ref.layout.mu_bytes / 1e6:.1f} MB)")
print(f"device cap    : {DEVICE_CAP_BYTES / 1e6:8.1f} MB "
      f"(store is {store_bytes / DEVICE_CAP_BYTES:.1f}x larger)\n")

print(f"{'tier':8s} {'driver':10s} {'wall_s':>7s} {'disk_read':>12s} "
      f"{'disk_write':>12s} {'overlap':>8s}")
print(f"{'device':8s} {'async':10s} {t_ref:7.2f} {'-':>12s} {'-':>12s} "
      f"{'-':>8s}")

with tempfile.TemporaryDirectory() as td:
    for driver in ("explicit", "async"):
        t0 = time.perf_counter()
        out, pems = psrs_sort(
            data, v=v, k=k, driver=driver,
            tier="memmap", backing_path=os.path.join(td, f"{driver}.bin"),
            device_cap_bytes=DEVICE_CAP_BYTES,
            return_pems=True,
        )
        dt = time.perf_counter() - t0
        assert (out == ref).all(), "out-of-core sort diverged from in-memory"
        led, ts = pems.ledger, pems.tier_stats
        print(f"{'memmap':8s} {driver:10s} {dt:7.2f} "
              f"{led.disk_read_bytes:12,} {led.disk_write_bytes:12,} "
              f"{ts.overlap_fraction:8.2%}")

    if args.io_driver is not None:
        io_drivers = (("buffered", "odirect", "mmap")
                      if args.io_driver == "all" else (args.io_driver,))
        print(f"\nfile tier (repro.io engine, queue depth "
              f"{args.io_queue_depth}):")
        print(f"{'io_driver':10s} {'driver':10s} {'wall_s':>7s} "
              f"{'syscall_rd':>12s} {'syscall_wr':>12s} {'overlap':>8s} "
              f"{'depth':>5s} {'rw_ovl':>6s}")
        for io_driver in io_drivers:
            for driver in ("explicit", "async"):
                t0 = time.perf_counter()
                out, pems = psrs_sort(
                    data, v=v, k=k, driver=driver, tier="file",
                    io_driver=io_driver,
                    io_queue_depth=args.io_queue_depth,
                    backing_path=os.path.join(
                        td, f"{io_driver}-{driver}.bin"),
                    device_cap_bytes=DEVICE_CAP_BYTES,
                    return_pems=True,
                )
                dt = time.perf_counter() - t0
                assert (out == ref).all(), \
                    "file-tier sort diverged from in-memory"
                led, ts = pems.ledger, pems.tier_stats
                print(f"{io_driver:10s} {driver:10s} {dt:7.2f} "
                      f"{led.syscall_read_bytes:12,} "
                      f"{led.syscall_write_bytes:12,} "
                      f"{ts.overlap_fraction:8.2%} {ts.max_queue_depth:5d} "
                      f"{ts.rw_overlap_events:6d}")

print("\nout-of-core result bit-identical to the in-memory run")

if args.inject_faults:
    SPEC = "seed=5;eio@p0.03:x2;lat@p0.02:0.001"
    print(f"\nfault tolerance (fault_spec={SPEC!r}):")
    with tempfile.TemporaryDirectory() as td:
        t0 = time.perf_counter()
        out, pems = psrs_sort(
            data, v=v, k=2, driver="async", tier="file",
            io_driver="faulty:buffered", fault_spec=SPEC, io_retries=4,
            checksums=True, io_queue_depth=args.io_queue_depth,
            backing_path=os.path.join(td, "faulty.bin"), return_pems=True)
        dt = time.perf_counter() - t0
        assert (out == want).all(), "faulted sort diverged"
        inj, ts = pems.backing.file.injected, pems.tier_stats
        print(f"  survived seeded faults in {dt:.2f}s: injected "
              f"eio={inj['eio']} lat={inj['lat']}; engine retries="
              f"{ts.retries} backoff={ts.backoff_s * 1e3:.1f}ms "
              f"permanent_errors={ts.permanent_errors}")

        # kill -9 mid-stage, then resume from the durable superstep cursor.
        state = os.path.join(td, "state")
        child = textwrap.dedent(f"""
            import sys
            import numpy as np
            from repro.pems_apps import psrs_run_recoverable
            rng = np.random.default_rng(1)
            data = rng.integers(-2**31, 2**31 - 1, size={n}, dtype=np.int32)
            psrs_run_recoverable(data, v={v}, k=2, state_dir=sys.argv[1],
                                 io_driver="buffered",
                                 crash_in_stage="merge")
        """)
        r = subprocess.run([sys.executable, "-c", child, state],
                           capture_output=True, text=True, timeout=600)
        assert r.returncode == -signal.SIGKILL, (r.returncode,
                                                 r.stderr[-2000:])
        print(f"  child killed -9 mid-'merge' (exit {r.returncode}); "
              "cursor + checksummed backing left behind — resuming ...")
        t0 = time.perf_counter()
        out2 = psrs_run_recoverable(data, v=v, k=2, state_dir=state,
                                    io_driver="buffered")
        assert (np.asarray(out2) == want).all(), "resumed sort diverged"
        print(f"  resumed from the superstep cursor in "
              f"{time.perf_counter() - t0:.2f}s; output bit-identical to "
              "the uninterrupted run")

print("\nPEMS2 direct vs PEMS1 indirect delivery (same sort, device tier):")
for mode in ("direct", "indirect"):
    t0 = time.perf_counter()
    out, pems = psrs_sort(data, v=16, k=4, mode=mode, return_pems=True)
    dt = time.perf_counter() - t0
    led = pems.ledger
    print(f"  {mode:9s} wall={dt:6.2f}s io={led.io_total:14,} "
          f"disk={led.disk_space:14,}")
