"""Batched serving example: prefill + decode with KV caches (transformer)
and O(1) recurrent state (mamba2), via the production serve driver.

    PYTHONPATH=src python examples/serve_lm.py
"""

from repro.launch.serve import main

print("=== transformer (qwen2-family, KV cache) ===")
main(["--arch", "qwen2-1.5b", "--smoke", "--requests", "8",
      "--prompt-len", "16", "--gen-len", "32"])

print("\n=== SSM (mamba2-family, O(1) state) ===")
main(["--arch", "mamba2-130m", "--smoke", "--requests", "8",
      "--prompt-len", "16", "--gen-len", "32"])
