"""End-to-end training driver: train a reduced Qwen2-family model for a few
hundred steps on CPU with checkpointing, then resume to show crash recovery.

    PYTHONPATH=src python examples/train_lm.py
"""

import tempfile

from repro.launch.train import main

with tempfile.TemporaryDirectory() as d:
    print("=== training 200 steps ===")
    main([
        "--arch", "qwen2-1.5b", "--smoke",
        "--steps", "200", "--seq", "64", "--batch", "8",
        "--microbatches", "2",
        "--ckpt-dir", d, "--ckpt-every", "100",
    ])
    print("\n=== simulated restart: resumes from step 200 checkpoint ===")
    main([
        "--arch", "qwen2-1.5b", "--smoke",
        "--steps", "200", "--seq", "64", "--batch", "8",
        "--microbatches", "2",
        "--ckpt-dir", d,
    ])
